//! Deterministic CXL.mem RAS fault injection and availability lifecycle.
//!
//! A [`FaultPlan`] schedules four kinds of CXL RAS events against
//! topology pools, resolved at every epoch barrier in plan order on
//! all three drivers (sequential, batched, multihost):
//!
//! * **retry storm** — transient CRC-retry pressure: per-pool read /
//!   write latency inflated by a fixed ns for a window of epochs;
//! * **link retraining** — every switch row on the pool's path to the
//!   root has its bandwidth scaled by a fraction for a window of
//!   epochs;
//! * **pool offline** — device hot-remove: the pool's live regions
//!   fail over to the fallback pool through the cost-modeled
//!   migration machinery, and policies see the reduced pool set;
//! * **pool online** — ends a prior `offline` window on the same
//!   pool: the pool rejoins the topology with a warm-up window of
//!   `warmup_epochs` during which a transient latency adder decays
//!   linearly from its full value to zero (cold device caches /
//!   retrained link), so availability scenarios round-trip
//!   offline → failover → recovery → re-balance.
//!
//! Plans are written as a TOML file (`--faults plan.toml`), inline
//! (`--fault "storm:pool1@5+10:rd=200,wr=300;offline:pool0@12"`), or
//! generated from a seeded MTBF soak spec
//! ([`FaultPlan::generate`], `--fault-soak "mtbf=200,seed=7"`) that
//! draws exponential inter-arrival times from the repo's own
//! deterministic [`crate::util::rng::Rng`] — same spec + same seed is
//! bit-identical everywhere. Pool references hold *names* (or integer
//! pool ids) until [`FaultPlan::resolve`] binds them against a
//! concrete [`Topology`], which keeps `SimConfig`
//! topology-independent. An optional seeded jitter (`seed` +
//! `jitter_epochs`) perturbs start epochs at resolve time, in plan
//! order.
//!
//! In multihost runs an event may carry a `host = "h1"` scope:
//! [`FaultPlan::split_hosts`] routes it into that host's private
//! sub-plan (only retry storms may be host-scoped — retraining and
//! hot-remove are fabric-wide), and the coordinator advances the
//! per-host schedules at the barrier in host order.
//!
//! At run time a [`FaultState`] owns the resolved schedule: the driver
//! calls [`FaultState::epoch_begin`] at each barrier, which
//! activates / expires windows and rebuilds the additive / multiplicative
//! [`FaultOverlay`] that the analyzer applies over its base tensors.
//! Every warm-up decay step is a revision edge, so the batched and
//! pipelined drivers flush their pending groups and each epoch is
//! analyzed under its own overlay. The fault-free path never
//! constructs any of this.

use crate::topology::{PoolId, Topology};
use crate::util::rng::Rng;
use crate::util::toml::TomlDoc;
use std::fmt;

/// Structured fault-subsystem error; every variant renders as a clean
/// one-line message (no panics on user-reachable paths).
#[derive(Debug, Clone, PartialEq)]
pub enum FaultError {
    /// Spec references a pool the topology does not have.
    UnknownPool(String),
    /// A transient fault (storm / retrain) with a zero-length window.
    ZeroWindow(String),
    /// An offline event targets a pool whose previous offline window
    /// was never closed by an `online` event.
    OverlappingOffline(String),
    /// An online event targets a pool that has no open offline window.
    OnlineWithoutOffline(String),
    /// A host-scoped event is invalid (bad host name, non-storm kind,
    /// or a host-scoped plan handed to a single-host driver).
    HostScope(String),
    /// Every pool (including local DRAM) is offline: no reachable pool
    /// is left to fail over to.
    NoReachablePool,
    /// Malformed plan text (TOML, inline spec, or soak spec).
    Parse(String),
}

impl fmt::Display for FaultError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultError::UnknownPool(p) => {
                write!(f, "fault plan: unknown pool `{p}` (use a pool name or pool id)")
            }
            FaultError::ZeroWindow(p) => {
                write!(f, "fault plan: zero-length window for transient fault on `{p}`")
            }
            FaultError::OverlappingOffline(p) => {
                write!(f, "fault plan: pool `{p}` is taken offline more than once")
            }
            FaultError::OnlineWithoutOffline(p) => {
                write!(f, "fault plan: `online` on pool `{p}` without a prior open `offline`")
            }
            FaultError::HostScope(m) => write!(f, "fault plan: {m}"),
            FaultError::NoReachablePool => {
                write!(f, "fault degradation: all pools offline, no reachable pool to fail over to")
            }
            FaultError::Parse(m) => write!(f, "fault plan: {m}"),
        }
    }
}

impl std::error::Error for FaultError {}

/// What a fault does while its window is active.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// CRC retry storm: additive per-event latency on the pool.
    RetryStorm { rd_add_ns: f32, wr_add_ns: f32 },
    /// Link retraining: path bandwidth scaled to `frac` of nominal.
    LinkRetrain { frac: f32 },
    /// Device hot-remove; permanent unless a later `PoolOnline` event
    /// closes the window.
    PoolOffline,
    /// Device hot-add ending a prior offline window: the pool rejoins
    /// the topology and serves traffic under a transient latency adder
    /// that decays linearly to zero over `warmup_epochs`.
    PoolOnline { warmup_epochs: u64, rd_add_ns: f32, wr_add_ns: f32 },
}

/// One scheduled event, pool still by name (or numeric id string).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    pub pool: String,
    /// First epoch (0-based) the fault is active in.
    pub start: u64,
    /// Window length in epochs; ignored for `PoolOffline` (open until
    /// a matching `PoolOnline`) and `PoolOnline` (whose window is its
    /// `warmup_epochs`).
    pub epochs: u64,
    pub kind: FaultKind,
    /// Multihost scope: `None` = fabric-wide (every host), `Some("h1")`
    /// = only host 1's traffic sees it. Only retry storms may be
    /// host-scoped; single-host drivers reject host-scoped plans.
    pub host: Option<String>,
}

/// A parsed, unresolved fault schedule (part of `SimConfig`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub seed: u64,
    /// Max epochs of seeded start jitter (0 = starts taken verbatim).
    pub jitter_epochs: u64,
    pub events: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse the TOML plan format:
    ///
    /// ```toml
    /// seed = 42            # optional, default 0
    /// jitter_epochs = 0    # optional
    /// [[fault]]
    /// kind = "storm"       # storm | retrain | offline | online
    /// pool = "pool1"       # pool name or numeric pool id
    /// start = 5
    /// epochs = 10          # required for storm/retrain
    /// rd_add_ns = 200      # storm / online warm-up adder
    /// wr_add_ns = 300      # storm / online warm-up adder
    /// frac = 0.5           # retrain only
    /// warmup_epochs = 4    # online only (default 0 = instant)
    /// host = "h1"          # optional multihost scope (storms only)
    /// ```
    pub fn parse_toml(src: &str) -> Result<FaultPlan, FaultError> {
        let doc = TomlDoc::parse(src).map_err(FaultError::Parse)?;
        let top = doc.table("").cloned().unwrap_or_default();
        let num = |t: &crate::util::toml::Table, k: &str, d: f64| {
            t.get(k).and_then(|v| v.as_f64()).unwrap_or(d)
        };
        let mut plan = FaultPlan {
            seed: num(&top, "seed", 0.0) as u64,
            jitter_epochs: num(&top, "jitter_epochs", 0.0) as u64,
            events: Vec::new(),
        };
        for (i, t) in doc.array("fault").iter().enumerate() {
            let ctx = format!("[[fault]] #{}", i + 1);
            let kind_s = t
                .get("kind")
                .and_then(|v| v.as_str())
                .ok_or_else(|| FaultError::Parse(format!("{ctx}: missing `kind`")))?;
            let pool = t
                .get("pool")
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
                .or_else(|| t.get("pool").and_then(|v| v.as_f64()).map(|n| format!("{n}")))
                .ok_or_else(|| FaultError::Parse(format!("{ctx}: missing `pool`")))?;
            let start = num(t, "start", 0.0) as u64;
            let epochs = num(t, "epochs", 0.0) as u64;
            let host = t.get("host").and_then(|v| v.as_str()).map(|s| s.to_string());
            let kind = match kind_s {
                "storm" => FaultKind::RetryStorm {
                    rd_add_ns: num(t, "rd_add_ns", 0.0) as f32,
                    wr_add_ns: num(t, "wr_add_ns", 0.0) as f32,
                },
                "retrain" => {
                    let frac = num(t, "frac", 0.5) as f32;
                    if !(frac > 0.0 && frac <= 1.0) {
                        return Err(FaultError::Parse(format!(
                            "{ctx}: `frac` must be in (0, 1], got {frac}"
                        )));
                    }
                    FaultKind::LinkRetrain { frac }
                }
                "offline" => FaultKind::PoolOffline,
                "online" => FaultKind::PoolOnline {
                    warmup_epochs: num(t, "warmup_epochs", 0.0) as u64,
                    rd_add_ns: num(t, "rd_add_ns", 0.0) as f32,
                    wr_add_ns: num(t, "wr_add_ns", 0.0) as f32,
                },
                other => {
                    return Err(FaultError::Parse(format!(
                        "{ctx}: unknown kind `{other}` (storm | retrain | offline | online)"
                    )))
                }
            };
            plan.events.push(FaultSpec { pool, start, epochs, kind, host });
        }
        if plan.events.is_empty() {
            return Err(FaultError::Parse("no [[fault]] entries in plan".into()));
        }
        Ok(plan)
    }

    /// Parse the inline one-flag form: `;`-separated events, each
    /// `kind:pool@start[+epochs][:k=v,...]`, e.g.
    ///
    /// ```text
    /// storm:pool1@5+10:rd=200,wr=300;offline:pool0@12;online:pool0@20:warmup=4,rd=100
    /// ```
    ///
    /// Params: `rd` / `wr` (storm or online warm-up adder, ns),
    /// `frac` (retrain), `warmup` (online window, epochs), `host`
    /// (multihost scope, storms only).
    pub fn parse_inline(spec: &str) -> Result<FaultPlan, FaultError> {
        let mut plan = FaultPlan::default();
        for ev in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            let mut parts = ev.splitn(3, ':');
            let kind_s = parts.next().unwrap_or_default();
            let target = parts
                .next()
                .ok_or_else(|| FaultError::Parse(format!("`{ev}`: missing pool@start")))?;
            let params = parts.next().unwrap_or("");
            let (pool, when) = target
                .split_once('@')
                .ok_or_else(|| FaultError::Parse(format!("`{ev}`: expected pool@start")))?;
            let (start_s, epochs_s) = match when.split_once('+') {
                Some((s, e)) => (s, Some(e)),
                None => (when, None),
            };
            let start: u64 = start_s
                .parse()
                .map_err(|_| FaultError::Parse(format!("`{ev}`: bad start epoch `{start_s}`")))?;
            let epochs: u64 = match epochs_s {
                Some(e) => e
                    .parse()
                    .map_err(|_| FaultError::Parse(format!("`{ev}`: bad window `{e}`")))?,
                None => 0,
            };
            let mut host = None;
            let mut kv = std::collections::BTreeMap::new();
            for p in params.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                let (k, v) = p
                    .split_once('=')
                    .ok_or_else(|| FaultError::Parse(format!("`{ev}`: bad param `{p}`")))?;
                if k.trim() == "host" {
                    host = Some(v.trim().to_string());
                    continue;
                }
                let v: f64 = v
                    .trim()
                    .parse()
                    .map_err(|_| FaultError::Parse(format!("`{ev}`: bad value in `{p}`")))?;
                kv.insert(k.trim().to_string(), v);
            }
            let kind = match kind_s {
                "storm" => FaultKind::RetryStorm {
                    rd_add_ns: kv.get("rd").copied().unwrap_or(0.0) as f32,
                    wr_add_ns: kv.get("wr").copied().unwrap_or(0.0) as f32,
                },
                "retrain" => {
                    let frac = kv.get("frac").copied().unwrap_or(0.5) as f32;
                    if !(frac > 0.0 && frac <= 1.0) {
                        return Err(FaultError::Parse(format!(
                            "`{ev}`: `frac` must be in (0, 1], got {frac}"
                        )));
                    }
                    FaultKind::LinkRetrain { frac }
                }
                "offline" => FaultKind::PoolOffline,
                "online" => FaultKind::PoolOnline {
                    warmup_epochs: kv.get("warmup").copied().unwrap_or(0.0) as u64,
                    rd_add_ns: kv.get("rd").copied().unwrap_or(0.0) as f32,
                    wr_add_ns: kv.get("wr").copied().unwrap_or(0.0) as f32,
                },
                other => {
                    return Err(FaultError::Parse(format!(
                        "`{ev}`: unknown kind `{other}` (storm | retrain | offline | online)"
                    )))
                }
            };
            plan.events.push(FaultSpec {
                pool: pool.trim().to_string(),
                start,
                epochs,
                kind,
                host,
            });
        }
        if plan.events.is_empty() {
            return Err(FaultError::Parse("empty fault spec".into()));
        }
        Ok(plan)
    }

    /// Generate a seeded MTBF soak plan from a comma-separated spec,
    /// e.g. `"mtbf=200,kinds=storm|retrain|offline+online,seed=7"`.
    ///
    /// Keys: `mtbf` (mean epochs between events, required), `kinds`
    /// (pipe-separated from `storm`, `retrain`, `offline`,
    /// `offline+online`; default `storm|retrain|offline+online`),
    /// `epochs` (horizon, default 1000), `window` (mean window /
    /// outage length, default `max(mtbf/4, 1)`), `pools`
    /// (pipe-separated names or ids, default `1`), `rd` / `wr` (storm
    /// and warm-up adders, default 250 / 125 ns), `frac` (retrain,
    /// default 0.5), `warmup` (re-online warm-up epochs, default 2),
    /// `seed` (overrides the function argument).
    ///
    /// Inter-arrival times and window lengths are exponential draws
    /// from the repo's deterministic RNG, so the same spec + seed is
    /// bit-identical everywhere. An `offline` draw on a pool that is
    /// already down (or permanently removed) is emitted as a storm
    /// instead, keeping the draw sequence — and thus the whole plan —
    /// deterministic while never violating the offline/online
    /// lifecycle.
    pub fn generate(seed: u64, spec: &str) -> Result<FaultPlan, FaultError> {
        let mut mtbf: Option<f64> = None;
        let mut kinds_s = "storm|retrain|offline+online".to_string();
        let mut horizon: u64 = 1000;
        let mut window: Option<f64> = None;
        let mut pools_s = "1".to_string();
        let mut rd = 250.0f64;
        let mut wr = 125.0f64;
        let mut frac = 0.5f64;
        let mut warmup: u64 = 2;
        let mut eff_seed = seed;
        let fnum = |k: &str, v: &str| -> Result<f64, FaultError> {
            v.parse::<f64>()
                .map_err(|_| FaultError::Parse(format!("soak spec: bad value `{v}` for `{k}`")))
        };
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (k, v) = part.split_once('=').ok_or_else(|| {
                FaultError::Parse(format!("soak spec: bad `{part}` (expected key=value)"))
            })?;
            let (k, v) = (k.trim(), v.trim());
            match k {
                "mtbf" => {
                    let m = fnum(k, v)?;
                    if !(m > 0.0) {
                        return Err(FaultError::Parse(format!(
                            "soak spec: `mtbf` must be > 0, got {v}"
                        )));
                    }
                    mtbf = Some(m);
                }
                "kinds" => kinds_s = v.to_string(),
                "epochs" => {
                    horizon = fnum(k, v)? as u64;
                    if horizon == 0 {
                        return Err(FaultError::Parse(format!(
                            "soak spec: `epochs` must be > 0, got {v}"
                        )));
                    }
                }
                "window" => {
                    let w = fnum(k, v)?;
                    if !(w > 0.0) {
                        return Err(FaultError::Parse(format!(
                            "soak spec: `window` must be > 0, got {v}"
                        )));
                    }
                    window = Some(w);
                }
                "pools" => pools_s = v.to_string(),
                "rd" => rd = fnum(k, v)?,
                "wr" => wr = fnum(k, v)?,
                "frac" => {
                    frac = fnum(k, v)?;
                    if !(frac > 0.0 && frac <= 1.0) {
                        return Err(FaultError::Parse(format!(
                            "soak spec: `frac` must be in (0, 1], got {v}"
                        )));
                    }
                }
                "warmup" => warmup = fnum(k, v)? as u64,
                "seed" => eff_seed = fnum(k, v)? as u64,
                other => {
                    return Err(FaultError::Parse(format!(
                        "soak spec: unknown key `{other}` (mtbf | kinds | epochs | window | \
                         pools | rd | wr | frac | warmup | seed)"
                    )))
                }
            }
        }
        let mtbf = mtbf
            .ok_or_else(|| FaultError::Parse("soak spec: `mtbf` is required".into()))?;
        let window = window.unwrap_or((mtbf / 4.0).max(1.0));
        let kinds: Vec<&str> = kinds_s.split('|').map(str::trim).filter(|s| !s.is_empty()).collect();
        if kinds.is_empty() {
            return Err(FaultError::Parse("soak spec: empty `kinds`".into()));
        }
        for k in &kinds {
            if !matches!(*k, "storm" | "retrain" | "offline" | "offline+online") {
                return Err(FaultError::Parse(format!(
                    "soak spec: unknown kind `{k}` (storm | retrain | offline | offline+online)"
                )));
            }
        }
        let pools: Vec<String> =
            pools_s.split('|').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
        if pools.is_empty() {
            return Err(FaultError::Parse("soak spec: empty `pools`".into()));
        }
        let mut rng = Rng::new(eff_seed ^ 0xfa17_50a6);
        let mut t = 0.0f64;
        // `gone`: permanently removed; `next_free`: first epoch a new
        // offline window may open on the pool (past any prior outage +
        // warm-up), so generated plans always satisfy the lifecycle.
        let mut gone = vec![false; pools.len()];
        let mut next_free = vec![0u64; pools.len()];
        let mut events = Vec::new();
        loop {
            t += rng.exponential(mtbf);
            let start = t.ceil() as u64;
            if start >= horizon {
                break;
            }
            let kind = kinds[rng.below(kinds.len() as u64) as usize];
            let pi = rng.below(pools.len() as u64) as usize;
            let wlen = rng.exponential(window).ceil().max(1.0) as u64;
            let pool = pools[pi].clone();
            let storm = |start: u64, wlen: u64| FaultSpec {
                pool: pool.clone(),
                start,
                epochs: wlen,
                kind: FaultKind::RetryStorm { rd_add_ns: rd as f32, wr_add_ns: wr as f32 },
                host: None,
            };
            match kind {
                "storm" => events.push(storm(start, wlen)),
                "retrain" => events.push(FaultSpec {
                    pool,
                    start,
                    epochs: wlen,
                    kind: FaultKind::LinkRetrain { frac: frac as f32 },
                    host: None,
                }),
                "offline" | "offline+online" => {
                    if gone[pi] {
                        // pool already removed for good — degrade the
                        // draw to a storm so the schedule stays valid
                        events.push(storm(start, wlen));
                        continue;
                    }
                    let start = start.max(next_free[pi]);
                    events.push(FaultSpec {
                        pool: pool.clone(),
                        start,
                        epochs: 0,
                        kind: FaultKind::PoolOffline,
                        host: None,
                    });
                    if kind == "offline+online" {
                        let up = start + wlen;
                        events.push(FaultSpec {
                            pool,
                            start: up,
                            epochs: 0,
                            kind: FaultKind::PoolOnline {
                                warmup_epochs: warmup,
                                rd_add_ns: rd as f32,
                                wr_add_ns: wr as f32,
                            },
                            host: None,
                        });
                        next_free[pi] = up + warmup + 1;
                    } else {
                        gone[pi] = true;
                    }
                }
                _ => unreachable!("kinds validated above"),
            }
        }
        Ok(FaultPlan { seed: eff_seed, jitter_epochs: 0, events })
    }

    /// Split a plan into the fabric-wide sub-plan and one sub-plan per
    /// host for the multihost coordinator. Host-scoped events must be
    /// retry storms (retraining and hot-remove affect the shared
    /// fabric, not one host's link) and must name a valid host
    /// (`"h1"` or `"1"`). Sub-plans inherit `seed` / `jitter_epochs`;
    /// jitter is drawn per sub-plan in plan order at resolve time.
    pub fn split_hosts(&self, nhosts: usize) -> Result<(FaultPlan, Vec<FaultPlan>), FaultError> {
        let sub = |events| FaultPlan { seed: self.seed, jitter_epochs: self.jitter_epochs, events };
        let mut global = Vec::new();
        let mut per_host: Vec<Vec<FaultSpec>> = (0..nhosts).map(|_| Vec::new()).collect();
        for spec in &self.events {
            match &spec.host {
                None => global.push(spec.clone()),
                Some(h) => {
                    let idx = h
                        .strip_prefix('h')
                        .unwrap_or(h)
                        .parse::<usize>()
                        .ok()
                        .filter(|&i| i < nhosts)
                        .ok_or_else(|| {
                            FaultError::HostScope(format!(
                                "unknown host `{h}` (hosts are h0..h{})",
                                nhosts.saturating_sub(1)
                            ))
                        })?;
                    if !matches!(spec.kind, FaultKind::RetryStorm { .. }) {
                        return Err(FaultError::HostScope(format!(
                            "host-scoped fault on `{}` must be a retry storm (retraining and \
                             hot-remove are fabric-wide)",
                            spec.pool
                        )));
                    }
                    let mut s = spec.clone();
                    s.host = None;
                    per_host[idx].push(s);
                }
            }
        }
        Ok((sub(global), per_host.into_iter().map(sub).collect()))
    }

    /// Bind pool names to ids against a concrete topology, validate the
    /// schedule, and apply the seeded start jitter — all in plan order,
    /// so the result is deterministic for a given (plan, topology).
    ///
    /// The offline/online lifecycle is validated here: an `offline`
    /// while the pool's previous offline window is still open is
    /// [`FaultError::OverlappingOffline`]; an `online` with no open
    /// window is [`FaultError::OnlineWithoutOffline`]. An `online`
    /// start is clamped to at least one epoch after its `offline` (so
    /// seeded jitter can never invert the pair) and closes the offline
    /// window at its own start.
    pub fn resolve(&self, topo: &Topology) -> Result<FaultState, FaultError> {
        let pools = topo.num_pools();
        let switches = topo.num_switches();
        let mut rng = Rng::new(self.seed ^ 0x5eed_fa17);
        // index into `events` of the pool's still-open offline window
        let mut open_offline: Vec<Option<usize>> = vec![None; pools];
        let mut events: Vec<ResolvedFault> = Vec::with_capacity(self.events.len());
        for spec in &self.events {
            if let Some(h) = &spec.host {
                return Err(FaultError::HostScope(format!(
                    "host-scoped fault (`host = \"{h}\"`) requires the multihost driver"
                )));
            }
            let pool = lookup_pool(topo, &spec.pool)
                .ok_or_else(|| FaultError::UnknownPool(spec.pool.clone()))?;
            let jitter =
                if self.jitter_epochs > 0 { rng.below(self.jitter_epochs + 1) } else { 0 };
            let start = spec.start + jitter;
            let (start, end, kind) = match &spec.kind {
                FaultKind::RetryStorm { rd_add_ns, wr_add_ns } => {
                    if spec.epochs == 0 {
                        return Err(FaultError::ZeroWindow(spec.pool.clone()));
                    }
                    (
                        start,
                        start + spec.epochs,
                        ResolvedKind::RetryStorm { rd: *rd_add_ns, wr: *wr_add_ns },
                    )
                }
                FaultKind::LinkRetrain { frac } => {
                    if spec.epochs == 0 {
                        return Err(FaultError::ZeroWindow(spec.pool.clone()));
                    }
                    // scale every switch row on the pool's path to root
                    let path = topo.path_to_root(pool);
                    let rows: Vec<usize> = (0..switches)
                        .filter(|&s| path.contains(&topo.switch_nodes()[s]))
                        .collect();
                    (start, start + spec.epochs, ResolvedKind::LinkRetrain { frac: *frac, rows })
                }
                FaultKind::PoolOffline => {
                    if open_offline[pool].is_some() {
                        return Err(FaultError::OverlappingOffline(spec.pool.clone()));
                    }
                    open_offline[pool] = Some(events.len());
                    (start, u64::MAX, ResolvedKind::PoolOffline)
                }
                FaultKind::PoolOnline { warmup_epochs, rd_add_ns, wr_add_ns } => {
                    let off = open_offline[pool]
                        .take()
                        .ok_or_else(|| FaultError::OnlineWithoutOffline(spec.pool.clone()))?;
                    let start = start.max(events[off].start + 1);
                    events[off].end = start;
                    (
                        start,
                        start + warmup_epochs,
                        ResolvedKind::PoolOnline { rd: *rd_add_ns, wr: *wr_add_ns },
                    )
                }
            };
            events.push(ResolvedFault { pool, start, end, kind, fired: false, active: false });
        }
        Ok(FaultState {
            events,
            overlay: FaultOverlay {
                extra_rd_add: vec![0.0; pools],
                extra_wr_add: vec![0.0; pools],
                bw_scale: vec![1.0; switches],
            },
            overlay_active: false,
            revision: 0,
            offline: vec![false; pools],
            degraded: vec![false; pools],
            storm_rd: vec![0.0; pools],
            storm_wr: vec![0.0; pools],
            warm_rd: vec![0.0; pools],
            warm_wr: vec![0.0; pools],
            faults_injected: 0,
            throttled_epochs: 0,
            pools_offline: 0,
            pools_reonlined: 0,
            retry_delay_ns: 0.0,
            warmup_delay_ns: 0.0,
            failover_migrated_bytes: 0,
        })
    }
}

/// Accept a pool name (`"pool1"`, `"local"`) or a numeric pool id.
fn lookup_pool(topo: &Topology, name: &str) -> Option<PoolId> {
    for p in 0..topo.num_pools() {
        if topo.pool_name(p) == name {
            return Some(p);
        }
    }
    let p: PoolId = name.parse().ok()?;
    (p < topo.num_pools()).then_some(p)
}

/// Per-epoch additive / multiplicative modifiers the analyzer applies
/// over its base tensors. Identity when no fault window is active —
/// and then the analyzer is never even handed one.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultOverlay {
    /// Additive ns per read event, `[P]`.
    pub extra_rd_add: Vec<f32>,
    /// Additive ns per write event, `[P]`.
    pub extra_wr_add: Vec<f32>,
    /// Multiplicative bandwidth scale per switch row, `[S]`.
    pub bw_scale: Vec<f32>,
}

#[derive(Debug, Clone)]
enum ResolvedKind {
    RetryStorm { rd: f32, wr: f32 },
    LinkRetrain { frac: f32, rows: Vec<usize> },
    PoolOffline,
    /// Warm-up adders at full strength; the per-epoch overlay scales
    /// them by the remaining fraction of the window.
    PoolOnline { rd: f32, wr: f32 },
}

#[derive(Debug, Clone)]
struct ResolvedFault {
    pool: PoolId,
    start: u64,
    /// Exclusive end epoch; `u64::MAX` for never-recovered offlines.
    end: u64,
    kind: ResolvedKind,
    /// Counted toward `faults_injected` (once per event).
    fired: bool,
    /// Was active last epoch — edge detection for overlay rebuilds.
    active: bool,
}

/// Runtime fault schedule: owned by the driver, advanced once per
/// epoch at the barrier, identical on all three drivers.
#[derive(Debug, Clone)]
pub struct FaultState {
    events: Vec<ResolvedFault>,
    overlay: FaultOverlay,
    overlay_active: bool,
    /// Bumped whenever the active overlay changes — membership edges
    /// *and* every warm-up decay step; the batched driver flushes its
    /// pending group early on a revision edge so every epoch is
    /// analyzed under its own overlay.
    revision: u64,
    /// Offline mask, `[P]` — pools currently removed (an `online`
    /// event clears the bit again).
    pub offline: Vec<bool>,
    /// Degraded mask, `[P]` — pools targeted by an active storm,
    /// retrain, or re-online warm-up window; the `drain` policy reads
    /// this through `PolicyCtx` to proactively evacuate hot regions
    /// and gate re-admission.
    degraded: Vec<bool>,
    /// Currently-active storm adds, `[P]` — the exact stage-1 latency
    /// attribution basis for `retry_delay_ns`.
    storm_rd: Vec<f32>,
    storm_wr: Vec<f32>,
    /// Currently-active warm-up adds, `[P]` (already decay-scaled) —
    /// the attribution basis for `warmup_delay_ns`.
    warm_rd: Vec<f32>,
    warm_wr: Vec<f32>,
    /// Scheduled events fired so far (recoveries included).
    pub faults_injected: u64,
    /// Epochs with at least one active transient window (storm,
    /// retrain, or warm-up).
    pub throttled_epochs: u64,
    /// Pool-offline transitions fired (a re-onlined pool going down
    /// again counts again).
    pub pools_offline: u64,
    /// Pool-online transitions fired (offline windows closed).
    pub pools_reonlined: u64,
    /// Total extra latency injected by retry storms (exact: stage-1 is
    /// linear, so this is `Σ_p reads(p)·rd_add(p) + writes(p)·wr_add(p)`
    /// over post-injection bins — a sub-component of `lat_delay_ns`,
    /// not an addition to it).
    pub retry_delay_ns: f64,
    /// Total extra latency injected by re-online warm-up adders, with
    /// the same exact stage-1 attribution as `retry_delay_ns`.
    pub warmup_delay_ns: f64,
    /// Bytes evacuated off offline pools by graceful degradation.
    pub failover_migrated_bytes: u64,
}

impl FaultState {
    /// Advance the schedule to `epoch` (0-based). Activates and
    /// expires windows in plan order, rebuilds the overlay on any
    /// membership edge *and* on every active warm-up epoch (the decay
    /// step changes the overlay), and returns `true` when the overlay
    /// revision changed (the batched driver's early-flush signal).
    pub fn epoch_begin(&mut self, epoch: u64) -> bool {
        let mut changed = false;
        let mut any_transient = false;
        let mut warming = false;
        for ev in &mut self.events {
            let active = epoch >= ev.start && epoch < ev.end;
            if epoch >= ev.start && !ev.fired {
                ev.fired = true;
                self.faults_injected += 1;
                match &ev.kind {
                    ResolvedKind::PoolOffline => {
                        if !self.offline[ev.pool] {
                            self.offline[ev.pool] = true;
                            self.pools_offline += 1;
                        }
                    }
                    ResolvedKind::PoolOnline { .. } => {
                        if self.offline[ev.pool] {
                            self.offline[ev.pool] = false;
                            self.pools_reonlined += 1;
                        }
                        // a zero-warmup online never activates a
                        // window, but the mask edge must still bump
                        // the revision
                        changed = true;
                    }
                    _ => {}
                }
            }
            if active != ev.active {
                ev.active = active;
                changed = true;
            }
            if active {
                match &ev.kind {
                    ResolvedKind::PoolOffline => {}
                    ResolvedKind::PoolOnline { rd, wr } => {
                        any_transient = true;
                        if *rd != 0.0 || *wr != 0.0 {
                            warming = true;
                        }
                    }
                    _ => any_transient = true,
                }
            }
        }
        if any_transient {
            self.throttled_epochs += 1;
        }
        if changed || warming {
            self.rebuild_overlay(epoch);
            self.revision += 1;
            changed = true;
        }
        changed
    }

    fn rebuild_overlay(&mut self, epoch: u64) {
        self.overlay.extra_rd_add.iter_mut().for_each(|v| *v = 0.0);
        self.overlay.extra_wr_add.iter_mut().for_each(|v| *v = 0.0);
        self.overlay.bw_scale.iter_mut().for_each(|v| *v = 1.0);
        self.storm_rd.iter_mut().for_each(|v| *v = 0.0);
        self.storm_wr.iter_mut().for_each(|v| *v = 0.0);
        self.warm_rd.iter_mut().for_each(|v| *v = 0.0);
        self.warm_wr.iter_mut().for_each(|v| *v = 0.0);
        self.degraded.iter_mut().for_each(|v| *v = false);
        let mut any = false;
        for ev in &self.events {
            if !ev.active {
                continue;
            }
            match &ev.kind {
                ResolvedKind::RetryStorm { rd, wr } => {
                    self.overlay.extra_rd_add[ev.pool] += rd;
                    self.overlay.extra_wr_add[ev.pool] += wr;
                    self.storm_rd[ev.pool] += rd;
                    self.storm_wr[ev.pool] += wr;
                    self.degraded[ev.pool] = true;
                    any = true;
                }
                ResolvedKind::LinkRetrain { frac, rows } => {
                    for &s in rows {
                        self.overlay.bw_scale[s] *= frac;
                    }
                    self.degraded[ev.pool] = true;
                    any = true;
                }
                ResolvedKind::PoolOffline => {}
                ResolvedKind::PoolOnline { rd, wr } => {
                    // linear decay: full adder on the first warm-up
                    // epoch, 1/warmup of it on the last
                    self.degraded[ev.pool] = true;
                    let warmup = (ev.end - ev.start).max(1);
                    let f = ev.end.saturating_sub(epoch) as f32 / warmup as f32;
                    let (r, w) = (rd * f, wr * f);
                    if r != 0.0 || w != 0.0 {
                        self.overlay.extra_rd_add[ev.pool] += r;
                        self.overlay.extra_wr_add[ev.pool] += w;
                        self.warm_rd[ev.pool] += r;
                        self.warm_wr[ev.pool] += w;
                        any = true;
                    }
                }
            }
        }
        self.overlay_active = any;
    }

    /// The overlay the analyzer should run this epoch under, or `None`
    /// when every modifier is identity (the fault-free fast path).
    pub fn overlay(&self) -> Option<&FaultOverlay> {
        if self.overlay_active {
            Some(&self.overlay)
        } else {
            None
        }
    }

    /// Current overlay revision (monotonic; bumped on membership edges
    /// and warm-up decay steps).
    pub fn revision(&self) -> u64 {
        self.revision
    }

    /// Pools currently in a degraded-but-serving window (storm,
    /// retrain, or re-online warm-up) — the `drain` policy's input.
    pub fn degraded(&self) -> &[bool] {
        &self.degraded
    }

    /// Attribute this epoch's injected latency from post-injection
    /// `[P, B]` read/write totals: stage 1 of the analyzer is a linear
    /// dot product, so the storm and warm-up shares of `lat` are
    /// recoverable in closed form independent of epoch grouping or
    /// thread count. Accumulates into `retry_delay_ns` (storms) and
    /// `warmup_delay_ns` (re-online warm-up).
    pub fn attribute_epoch_delays(
        &mut self,
        read_count: impl Fn(PoolId) -> f64,
        write_count: impl Fn(PoolId) -> f64,
    ) {
        if !self.overlay_active {
            return;
        }
        let mut storm = 0.0f64;
        let mut warm = 0.0f64;
        for p in 0..self.storm_rd.len() {
            let (sr, hr) = (self.storm_rd[p] as f64, self.warm_rd[p] as f64);
            if sr != 0.0 || hr != 0.0 {
                let rc = read_count(p);
                storm += rc * sr;
                warm += rc * hr;
            }
            let (sw, hw) = (self.storm_wr[p] as f64, self.warm_wr[p] as f64);
            if sw != 0.0 || hw != 0.0 {
                let wc = write_count(p);
                storm += wc * sw;
                warm += wc * hw;
            }
        }
        self.retry_delay_ns += storm;
        self.warmup_delay_ns += warm;
    }

    /// Lowest-numbered online pool other than `from` (CXL pools first,
    /// then local DRAM), or the structured no-pool error.
    pub fn fallback_pool(&self, from: PoolId) -> Result<PoolId, FaultError> {
        for p in (1..self.offline.len()).chain(std::iter::once(0)) {
            if p != from && !self.offline[p] {
                return Ok(p);
            }
        }
        Err(FaultError::NoReachablePool)
    }

    /// Any pool currently offline (checked by the caller against the
    /// tracker's per-pool byte accounting before sweeping).
    pub fn any_offline(&self) -> bool {
        self.offline.iter().any(|&b| b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::builtin;

    #[test]
    fn inline_roundtrip_and_kinds() {
        let p = FaultPlan::parse_inline(
            "storm:pool1@5+10:rd=200,wr=300;retrain:pool0@8+4:frac=0.5;offline:direct0@12",
        )
        .unwrap();
        assert_eq!(p.events.len(), 3);
        assert_eq!(
            p.events[0].kind,
            FaultKind::RetryStorm { rd_add_ns: 200.0, wr_add_ns: 300.0 }
        );
        assert_eq!(p.events[1].kind, FaultKind::LinkRetrain { frac: 0.5 });
        assert_eq!(p.events[2], FaultSpec {
            pool: "direct0".into(),
            start: 12,
            epochs: 0,
            kind: FaultKind::PoolOffline,
            host: None
        });
    }

    #[test]
    fn toml_plan_parses() {
        let src = r#"
seed = 7
[[fault]]
kind = "storm"
pool = "pool1"
start = 2
epochs = 3
rd_add_ns = 150
[[fault]]
kind = "offline"
pool = "pool0"
start = 4
[[fault]]
kind = "online"
pool = "pool0"
start = 9
warmup_epochs = 2
rd_add_ns = 80
"#;
        let p = FaultPlan::parse_toml(src).unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.events.len(), 3);
        assert_eq!(
            p.events[2].kind,
            FaultKind::PoolOnline { warmup_epochs: 2, rd_add_ns: 80.0, wr_add_ns: 0.0 }
        );
        assert!(p.resolve(&builtin::fig2()).is_ok());
    }

    #[test]
    fn resolve_rejects_bad_specs() {
        let topo = builtin::fig2();
        let unknown = FaultPlan::parse_inline("storm:nosuch@1+2:rd=10").unwrap();
        assert!(matches!(unknown.resolve(&topo), Err(FaultError::UnknownPool(_))));
        let zero = FaultPlan::parse_inline("storm:pool1@1+0:rd=10").unwrap();
        assert!(matches!(zero.resolve(&topo), Err(FaultError::ZeroWindow(_))));
        let overlap =
            FaultPlan::parse_inline("offline:pool1@1;offline:pool1@5").unwrap();
        assert!(matches!(overlap.resolve(&topo), Err(FaultError::OverlappingOffline(_))));
        let badfrac = FaultPlan::parse_inline("retrain:pool1@1+2:frac=1.5");
        assert!(matches!(badfrac, Err(FaultError::Parse(_))));
        let orphan = FaultPlan::parse_inline("online:pool1@5:warmup=2").unwrap();
        assert!(matches!(orphan.resolve(&topo), Err(FaultError::OnlineWithoutOffline(_))));
    }

    #[test]
    fn windows_activate_and_expire_with_revision_edges() {
        let topo = builtin::fig2();
        let plan = FaultPlan::parse_inline("storm:pool1@2+3:rd=100;offline:pool0@4").unwrap();
        let mut st = plan.resolve(&topo).unwrap();
        assert!(!st.epoch_begin(0));
        assert!(st.overlay().is_none());
        assert!(st.epoch_begin(2)); // storm opens
        let ov = st.overlay().unwrap();
        assert_eq!(ov.extra_rd_add[1], 100.0);
        assert!(!st.epoch_begin(3)); // still open, no edge
        assert!(st.epoch_begin(4)); // offline fires (edge), storm still open
        assert!(st.offline[1]); // pool0 is PoolId 1 in fig2
        assert_eq!(st.pools_offline, 1);
        assert!(st.epoch_begin(5)); // storm expires
        assert!(st.overlay().is_none(), "offline alone leaves the overlay identity");
        assert_eq!(st.faults_injected, 2);
        assert_eq!(st.throttled_epochs, 3); // epochs 2,3,4
    }

    #[test]
    fn online_reopens_pool_with_decaying_warmup() {
        let topo = builtin::fig2();
        let plan =
            FaultPlan::parse_inline("offline:pool0@4;online:pool0@8:warmup=2,rd=100,wr=50")
                .unwrap();
        let mut st = plan.resolve(&topo).unwrap();
        assert!(st.epoch_begin(4));
        assert!(st.offline[1]);
        assert!(!st.epoch_begin(5)); // outage in steady state: no edge
        assert!(st.epoch_begin(8)); // recovery: mask clears, warm-up opens
        assert!(!st.offline[1]);
        assert_eq!(st.pools_offline, 1);
        assert_eq!(st.pools_reonlined, 1);
        assert!(st.degraded()[1], "warming pool is degraded");
        let ov = st.overlay().unwrap();
        assert_eq!(ov.extra_rd_add[1], 100.0); // full adder, first epoch
        assert_eq!(ov.extra_wr_add[1], 50.0);
        let rev = st.revision();
        assert!(st.epoch_begin(9), "every decay step is a revision edge");
        assert_eq!(st.revision(), rev + 1);
        let ov = st.overlay().unwrap();
        assert_eq!(ov.extra_rd_add[1], 50.0); // half-way through the window
        assert_eq!(ov.extra_wr_add[1], 25.0);
        assert!(st.epoch_begin(10)); // warm-up expires
        assert!(st.overlay().is_none());
        assert!(!st.degraded()[1]);
        assert_eq!(st.throttled_epochs, 2); // epochs 8, 9
        st.attribute_epoch_delays(|_| 0.0, |_| 0.0);
        assert_eq!(st.warmup_delay_ns, 0.0);
    }

    #[test]
    fn offline_online_offline_round_trips() {
        let topo = builtin::fig2();
        let plan =
            FaultPlan::parse_inline("offline:pool0@2;online:pool0@5;offline:pool0@9").unwrap();
        let mut st = plan.resolve(&topo).unwrap();
        st.epoch_begin(2);
        assert!(st.offline[1]);
        assert!(st.epoch_begin(5), "zero-warmup online is still a revision edge");
        assert!(!st.offline[1]);
        assert!(st.overlay().is_none(), "zero-warmup online has no overlay");
        st.epoch_begin(9);
        assert!(st.offline[1]);
        assert_eq!(st.pools_offline, 2);
        assert_eq!(st.pools_reonlined, 1);
        assert_eq!(st.faults_injected, 3);
    }

    #[test]
    fn retrain_scales_path_rows_only() {
        let topo = builtin::fig2();
        // pool0 (PoolId 1) routes through sw0 and rc0 in fig2;
        // direct0 (PoolId 3) routes through rc0 only.
        let plan = FaultPlan::parse_inline("retrain:pool0@0+2:frac=0.25").unwrap();
        let mut st = plan.resolve(&topo).unwrap();
        st.epoch_begin(0);
        let ov = st.overlay().unwrap();
        let scaled: Vec<usize> =
            (0..ov.bw_scale.len()).filter(|&s| ov.bw_scale[s] != 1.0).collect();
        for &s in &scaled {
            assert!(topo.routes_through(1, topo.switch_nodes()[s]));
            assert_eq!(ov.bw_scale[s], 0.25);
        }
        assert!(!scaled.is_empty());
    }

    #[test]
    fn fallback_prefers_low_cxl_pool_then_local() {
        let topo = builtin::fig2();
        let plan = FaultPlan::parse_inline("offline:pool0@0").unwrap();
        let mut st = plan.resolve(&topo).unwrap();
        st.epoch_begin(0);
        assert_eq!(st.fallback_pool(1).unwrap(), 2); // pool1
        st.offline[2] = true;
        st.offline[3] = true;
        assert_eq!(st.fallback_pool(1).unwrap(), 0); // local DRAM last
        st.offline[0] = true;
        assert!(matches!(st.fallback_pool(1), Err(FaultError::NoReachablePool)));
    }

    #[test]
    fn seeded_jitter_is_deterministic_and_bounded() {
        let topo = builtin::fig2();
        let mut plan = FaultPlan::parse_inline("storm:pool1@10+2:rd=5").unwrap();
        plan.seed = 99;
        plan.jitter_epochs = 4;
        let a = plan.resolve(&topo).unwrap();
        let b = plan.resolve(&topo).unwrap();
        assert_eq!(a.events[0].start, b.events[0].start);
        assert!(a.events[0].start >= 10 && a.events[0].start <= 14);
    }

    #[test]
    fn jitter_never_inverts_an_offline_online_pair() {
        let topo = builtin::fig2();
        let mut plan =
            FaultPlan::parse_inline("offline:pool0@10;online:pool0@11:warmup=2").unwrap();
        plan.seed = 3;
        plan.jitter_epochs = 6;
        let st = plan.resolve(&topo).unwrap();
        assert!(st.events[1].start > st.events[0].start);
        assert_eq!(st.events[0].end, st.events[1].start);
    }

    #[test]
    fn numeric_pool_ids_accepted() {
        let topo = builtin::fig2();
        let plan = FaultPlan::parse_inline("storm:2@1+2:rd=5").unwrap();
        let st = plan.resolve(&topo).unwrap();
        assert_eq!(st.events[0].pool, 2);
        assert!(FaultPlan::parse_inline("storm:9@1+2:rd=5")
            .unwrap()
            .resolve(&topo)
            .is_err());
    }

    #[test]
    fn generated_soak_plans_are_deterministic_and_resolvable() {
        let topo = builtin::fig2();
        let a = FaultPlan::generate(7, "mtbf=20,epochs=1000").unwrap();
        let b = FaultPlan::generate(7, "mtbf=20,epochs=1000").unwrap();
        assert_eq!(a, b);
        assert!(!a.events.is_empty());
        assert!(a.resolve(&topo).is_ok(), "generated lifecycle must always validate");
        let c = FaultPlan::generate(8, "mtbf=20,epochs=1000").unwrap();
        assert_ne!(a, c);
        // an explicit seed key overrides the CLI seed argument
        let d = FaultPlan::generate(8, "mtbf=20,epochs=1000,seed=7").unwrap();
        assert_eq!(a, d);
        assert_eq!(a.jitter_epochs, 0);
    }

    #[test]
    fn soak_spec_rejects_bad_input() {
        assert!(matches!(
            FaultPlan::generate(0, "kinds=storm"),
            Err(FaultError::Parse(m)) if m.contains("mtbf")
        ));
        assert!(matches!(
            FaultPlan::generate(0, "mtbf=0"),
            Err(FaultError::Parse(_))
        ));
        assert!(matches!(
            FaultPlan::generate(0, "mtbf=50,kinds=storm|warp"),
            Err(FaultError::Parse(m)) if m.contains("warp")
        ));
        assert!(matches!(
            FaultPlan::generate(0, "mtbf=50,bogus=1"),
            Err(FaultError::Parse(m)) if m.contains("bogus")
        ));
        assert!(matches!(
            FaultPlan::generate(0, "mtbf=50,frac=2"),
            Err(FaultError::Parse(_))
        ));
    }

    #[test]
    fn split_hosts_routes_scoped_storms_and_validates() {
        let plan = FaultPlan::parse_inline(
            "storm:pool0@1+2:rd=10;storm:pool1@3+2:rd=20,host=h1;offline:pool0@9",
        )
        .unwrap();
        let (global, hosts) = plan.split_hosts(2).unwrap();
        assert_eq!(global.events.len(), 2);
        assert_eq!(hosts.len(), 2);
        assert!(hosts[0].events.is_empty());
        assert_eq!(hosts[1].events.len(), 1);
        assert_eq!(hosts[1].events[0].host, None, "scope is stripped after routing");
        assert_eq!(hosts[1].events[0].pool, "pool1");
        // bare numeric host names work too
        let plan2 = FaultPlan::parse_inline("storm:pool1@3+2:rd=20,host=0").unwrap();
        assert_eq!(plan2.split_hosts(1).unwrap().1[0].events.len(), 1);
        // unknown host
        assert!(matches!(
            plan.split_hosts(1),
            Err(FaultError::HostScope(m)) if m.contains("h1")
        ));
        // only storms may be host-scoped
        let off = FaultPlan::parse_inline("offline:pool0@9:host=h0").unwrap();
        assert!(matches!(off.split_hosts(2), Err(FaultError::HostScope(_))));
        // single-host drivers reject host-scoped plans at resolve time
        let topo = builtin::fig2();
        let scoped = FaultPlan::parse_inline("storm:pool1@3+2:rd=20,host=h1").unwrap();
        assert!(matches!(scoped.resolve(&topo), Err(FaultError::HostScope(_))));
    }
}

//! Deterministic CXL.mem RAS fault injection.
//!
//! A [`FaultPlan`] schedules three kinds of CXL RAS events against
//! topology pools, resolved at every epoch barrier in plan order on
//! all three drivers (sequential, batched, multihost):
//!
//! * **retry storm** — transient CRC-retry pressure: per-pool read /
//!   write latency inflated by a fixed ns for a window of epochs;
//! * **link retraining** — every switch row on the pool's path to the
//!   root has its bandwidth scaled by a fraction for a window of
//!   epochs;
//! * **pool offline** — permanent device hot-remove: the pool's live
//!   regions fail over to the fallback pool through the cost-modeled
//!   migration machinery, and policies see the reduced pool set.
//!
//! Plans are written either as a TOML file (`--faults plan.toml`) or
//! inline (`--fault "storm:pool1@5+10:rd=200,wr=300;offline:pool0@12"`).
//! Pool references hold *names* (or integer pool ids) until
//! [`FaultPlan::resolve`] binds them against a concrete [`Topology`],
//! which keeps `SimConfig` topology-independent. An optional seeded
//! jitter (`seed` + `jitter_epochs`) perturbs start epochs at resolve
//! time, in plan order, through the repo's own deterministic
//! [`crate::util::rng::Rng`] — same plan + same seed is bit-identical
//! everywhere.
//!
//! At run time a [`FaultState`] owns the resolved schedule: the driver
//! calls [`FaultState::epoch_begin`] at each barrier, which
//! activates / expires windows and rebuilds the additive / multiplicative
//! [`FaultOverlay`] that the analyzer applies over its base tensors.
//! The fault-free path never constructs any of this.

use crate::topology::{PoolId, Topology};
use crate::util::rng::Rng;
use crate::util::toml::TomlDoc;
use std::fmt;

/// Structured fault-subsystem error; every variant renders as a clean
/// one-line message (no panics on user-reachable paths).
#[derive(Debug, Clone, PartialEq)]
pub enum FaultError {
    /// Spec references a pool the topology does not have.
    UnknownPool(String),
    /// A transient fault (storm / retrain) with a zero-length window.
    ZeroWindow(String),
    /// Two offline events target the same pool.
    OverlappingOffline(String),
    /// Every pool (including local DRAM) is offline: no reachable pool
    /// is left to fail over to.
    NoReachablePool,
    /// Malformed plan text (TOML or inline spec).
    Parse(String),
}

impl fmt::Display for FaultError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultError::UnknownPool(p) => {
                write!(f, "fault plan: unknown pool `{p}` (use a pool name or pool id)")
            }
            FaultError::ZeroWindow(p) => {
                write!(f, "fault plan: zero-length window for transient fault on `{p}`")
            }
            FaultError::OverlappingOffline(p) => {
                write!(f, "fault plan: pool `{p}` is taken offline more than once")
            }
            FaultError::NoReachablePool => {
                write!(f, "fault degradation: all pools offline, no reachable pool to fail over to")
            }
            FaultError::Parse(m) => write!(f, "fault plan: {m}"),
        }
    }
}

impl std::error::Error for FaultError {}

/// What a fault does while its window is active.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// CRC retry storm: additive per-event latency on the pool.
    RetryStorm { rd_add_ns: f32, wr_add_ns: f32 },
    /// Link retraining: path bandwidth scaled to `frac` of nominal.
    LinkRetrain { frac: f32 },
    /// Permanent device hot-remove.
    PoolOffline,
}

/// One scheduled event, pool still by name (or numeric id string).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    pub pool: String,
    /// First epoch (0-based) the fault is active in.
    pub start: u64,
    /// Window length in epochs; ignored for `PoolOffline` (permanent).
    pub epochs: u64,
    pub kind: FaultKind,
}

/// A parsed, unresolved fault schedule (part of `SimConfig`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub seed: u64,
    /// Max epochs of seeded start jitter (0 = starts taken verbatim).
    pub jitter_epochs: u64,
    pub events: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse the TOML plan format:
    ///
    /// ```toml
    /// seed = 42            # optional, default 0
    /// jitter_epochs = 0    # optional
    /// [[fault]]
    /// kind = "storm"       # storm | retrain | offline
    /// pool = "pool1"       # pool name or numeric pool id
    /// start = 5
    /// epochs = 10          # required for storm/retrain
    /// rd_add_ns = 200      # storm only
    /// wr_add_ns = 300      # storm only
    /// frac = 0.5           # retrain only
    /// ```
    pub fn parse_toml(src: &str) -> Result<FaultPlan, FaultError> {
        let doc = TomlDoc::parse(src).map_err(FaultError::Parse)?;
        let top = doc.table("").cloned().unwrap_or_default();
        let num = |t: &crate::util::toml::Table, k: &str, d: f64| {
            t.get(k).and_then(|v| v.as_f64()).unwrap_or(d)
        };
        let mut plan = FaultPlan {
            seed: num(&top, "seed", 0.0) as u64,
            jitter_epochs: num(&top, "jitter_epochs", 0.0) as u64,
            events: Vec::new(),
        };
        for (i, t) in doc.array("fault").iter().enumerate() {
            let ctx = format!("[[fault]] #{}", i + 1);
            let kind_s = t
                .get("kind")
                .and_then(|v| v.as_str())
                .ok_or_else(|| FaultError::Parse(format!("{ctx}: missing `kind`")))?;
            let pool = t
                .get("pool")
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
                .or_else(|| t.get("pool").and_then(|v| v.as_f64()).map(|n| format!("{n}")))
                .ok_or_else(|| FaultError::Parse(format!("{ctx}: missing `pool`")))?;
            let start = num(t, "start", 0.0) as u64;
            let epochs = num(t, "epochs", 0.0) as u64;
            let kind = match kind_s {
                "storm" => FaultKind::RetryStorm {
                    rd_add_ns: num(t, "rd_add_ns", 0.0) as f32,
                    wr_add_ns: num(t, "wr_add_ns", 0.0) as f32,
                },
                "retrain" => {
                    let frac = num(t, "frac", 0.5) as f32;
                    if !(frac > 0.0 && frac <= 1.0) {
                        return Err(FaultError::Parse(format!(
                            "{ctx}: `frac` must be in (0, 1], got {frac}"
                        )));
                    }
                    FaultKind::LinkRetrain { frac }
                }
                "offline" => FaultKind::PoolOffline,
                other => {
                    return Err(FaultError::Parse(format!(
                        "{ctx}: unknown kind `{other}` (storm | retrain | offline)"
                    )))
                }
            };
            plan.events.push(FaultSpec { pool, start, epochs, kind });
        }
        if plan.events.is_empty() {
            return Err(FaultError::Parse("no [[fault]] entries in plan".into()));
        }
        Ok(plan)
    }

    /// Parse the inline one-flag form: `;`-separated events, each
    /// `kind:pool@start[+epochs][:k=v,...]`, e.g.
    ///
    /// ```text
    /// storm:pool1@5+10:rd=200,wr=300;retrain:pool0@8+4:frac=0.5;offline:direct0@12
    /// ```
    pub fn parse_inline(spec: &str) -> Result<FaultPlan, FaultError> {
        let mut plan = FaultPlan::default();
        for ev in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            let mut parts = ev.splitn(3, ':');
            let kind_s = parts.next().unwrap_or_default();
            let target = parts
                .next()
                .ok_or_else(|| FaultError::Parse(format!("`{ev}`: missing pool@start")))?;
            let params = parts.next().unwrap_or("");
            let (pool, when) = target
                .split_once('@')
                .ok_or_else(|| FaultError::Parse(format!("`{ev}`: expected pool@start")))?;
            let (start_s, epochs_s) = match when.split_once('+') {
                Some((s, e)) => (s, Some(e)),
                None => (when, None),
            };
            let start: u64 = start_s
                .parse()
                .map_err(|_| FaultError::Parse(format!("`{ev}`: bad start epoch `{start_s}`")))?;
            let epochs: u64 = match epochs_s {
                Some(e) => e
                    .parse()
                    .map_err(|_| FaultError::Parse(format!("`{ev}`: bad window `{e}`")))?,
                None => 0,
            };
            let mut kv = std::collections::BTreeMap::new();
            for p in params.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                let (k, v) = p
                    .split_once('=')
                    .ok_or_else(|| FaultError::Parse(format!("`{ev}`: bad param `{p}`")))?;
                let v: f64 = v
                    .trim()
                    .parse()
                    .map_err(|_| FaultError::Parse(format!("`{ev}`: bad value in `{p}`")))?;
                kv.insert(k.trim().to_string(), v);
            }
            let kind = match kind_s {
                "storm" => FaultKind::RetryStorm {
                    rd_add_ns: kv.get("rd").copied().unwrap_or(0.0) as f32,
                    wr_add_ns: kv.get("wr").copied().unwrap_or(0.0) as f32,
                },
                "retrain" => {
                    let frac = kv.get("frac").copied().unwrap_or(0.5) as f32;
                    if !(frac > 0.0 && frac <= 1.0) {
                        return Err(FaultError::Parse(format!(
                            "`{ev}`: `frac` must be in (0, 1], got {frac}"
                        )));
                    }
                    FaultKind::LinkRetrain { frac }
                }
                "offline" => FaultKind::PoolOffline,
                other => {
                    return Err(FaultError::Parse(format!(
                        "`{ev}`: unknown kind `{other}` (storm | retrain | offline)"
                    )))
                }
            };
            plan.events.push(FaultSpec { pool: pool.trim().to_string(), start, epochs, kind });
        }
        if plan.events.is_empty() {
            return Err(FaultError::Parse("empty fault spec".into()));
        }
        Ok(plan)
    }

    /// Bind pool names to ids against a concrete topology, validate the
    /// schedule, and apply the seeded start jitter — all in plan order,
    /// so the result is deterministic for a given (plan, topology).
    pub fn resolve(&self, topo: &Topology) -> Result<FaultState, FaultError> {
        let pools = topo.num_pools();
        let switches = topo.num_switches();
        let mut rng = Rng::new(self.seed ^ 0x5eed_fa17);
        let mut offline_seen = vec![false; pools];
        let mut events = Vec::with_capacity(self.events.len());
        for spec in &self.events {
            let pool = lookup_pool(topo, &spec.pool)
                .ok_or_else(|| FaultError::UnknownPool(spec.pool.clone()))?;
            let jitter =
                if self.jitter_epochs > 0 { rng.below(self.jitter_epochs + 1) } else { 0 };
            let start = spec.start + jitter;
            let (end, kind) = match &spec.kind {
                FaultKind::RetryStorm { rd_add_ns, wr_add_ns } => {
                    if spec.epochs == 0 {
                        return Err(FaultError::ZeroWindow(spec.pool.clone()));
                    }
                    (
                        start + spec.epochs,
                        ResolvedKind::RetryStorm { rd: *rd_add_ns, wr: *wr_add_ns },
                    )
                }
                FaultKind::LinkRetrain { frac } => {
                    if spec.epochs == 0 {
                        return Err(FaultError::ZeroWindow(spec.pool.clone()));
                    }
                    // scale every switch row on the pool's path to root
                    let path = topo.path_to_root(pool);
                    let rows: Vec<usize> = (0..switches)
                        .filter(|&s| path.contains(&topo.switch_nodes()[s]))
                        .collect();
                    (start + spec.epochs, ResolvedKind::LinkRetrain { frac: *frac, rows })
                }
                FaultKind::PoolOffline => {
                    if offline_seen[pool] {
                        return Err(FaultError::OverlappingOffline(spec.pool.clone()));
                    }
                    offline_seen[pool] = true;
                    (u64::MAX, ResolvedKind::PoolOffline)
                }
            };
            events.push(ResolvedFault { pool, start, end, kind, fired: false, active: false });
        }
        Ok(FaultState {
            events,
            overlay: FaultOverlay {
                extra_rd_add: vec![0.0; pools],
                extra_wr_add: vec![0.0; pools],
                bw_scale: vec![1.0; switches],
            },
            overlay_active: false,
            revision: 0,
            offline: vec![false; pools],
            storm_rd: vec![0.0; pools],
            storm_wr: vec![0.0; pools],
            faults_injected: 0,
            throttled_epochs: 0,
            pools_offline: 0,
            retry_delay_ns: 0.0,
            failover_migrated_bytes: 0,
        })
    }
}

/// Accept a pool name (`"pool1"`, `"local"`) or a numeric pool id.
fn lookup_pool(topo: &Topology, name: &str) -> Option<PoolId> {
    for p in 0..topo.num_pools() {
        if topo.pool_name(p) == name {
            return Some(p);
        }
    }
    let p: PoolId = name.parse().ok()?;
    (p < topo.num_pools()).then_some(p)
}

/// Per-epoch additive / multiplicative modifiers the analyzer applies
/// over its base tensors. Identity when no fault window is active —
/// and then the analyzer is never even handed one.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultOverlay {
    /// Additive ns per read event, `[P]`.
    pub extra_rd_add: Vec<f32>,
    /// Additive ns per write event, `[P]`.
    pub extra_wr_add: Vec<f32>,
    /// Multiplicative bandwidth scale per switch row, `[S]`.
    pub bw_scale: Vec<f32>,
}

#[derive(Debug, Clone)]
enum ResolvedKind {
    RetryStorm { rd: f32, wr: f32 },
    LinkRetrain { frac: f32, rows: Vec<usize> },
    PoolOffline,
}

#[derive(Debug, Clone)]
struct ResolvedFault {
    pool: PoolId,
    start: u64,
    /// Exclusive end epoch; `u64::MAX` for permanent events.
    end: u64,
    kind: ResolvedKind,
    /// Counted toward `faults_injected` (once per event).
    fired: bool,
    /// Was active last epoch — edge detection for overlay rebuilds.
    active: bool,
}

/// Runtime fault schedule: owned by the driver, advanced once per
/// epoch at the barrier, identical on all three drivers.
#[derive(Debug, Clone)]
pub struct FaultState {
    events: Vec<ResolvedFault>,
    overlay: FaultOverlay,
    overlay_active: bool,
    /// Bumped whenever the active overlay changes; the batched driver
    /// flushes its pending group early on a revision edge so every
    /// epoch is analyzed under its own overlay.
    revision: u64,
    /// Offline mask, `[P]` — pools permanently removed so far.
    pub offline: Vec<bool>,
    /// Currently-active storm adds, `[P]` — the exact stage-1 latency
    /// attribution basis for `retry_delay_ns`.
    storm_rd: Vec<f32>,
    storm_wr: Vec<f32>,
    /// Events whose window has opened at least once.
    pub faults_injected: u64,
    /// Epochs with at least one active transient window (storm or
    /// retrain).
    pub throttled_epochs: u64,
    /// Distinct pools taken offline.
    pub pools_offline: u64,
    /// Total extra latency injected by retry storms (exact: stage-1 is
    /// linear, so this is `Σ_p reads(p)·rd_add(p) + writes(p)·wr_add(p)`
    /// over post-injection bins — a sub-component of `lat_delay_ns`,
    /// not an addition to it).
    pub retry_delay_ns: f64,
    /// Bytes evacuated off offline pools by graceful degradation.
    pub failover_migrated_bytes: u64,
}

impl FaultState {
    /// Advance the schedule to `epoch` (0-based). Activates and
    /// expires windows in plan order, rebuilds the overlay on any
    /// membership edge, and returns `true` when the overlay revision
    /// changed (the batched driver's early-flush signal).
    pub fn epoch_begin(&mut self, epoch: u64) -> bool {
        let mut changed = false;
        let mut any_transient = false;
        for ev in &mut self.events {
            let active = epoch >= ev.start && epoch < ev.end;
            if active && !ev.fired {
                ev.fired = true;
                self.faults_injected += 1;
                if matches!(ev.kind, ResolvedKind::PoolOffline) && !self.offline[ev.pool] {
                    self.offline[ev.pool] = true;
                    self.pools_offline += 1;
                }
            }
            if active != ev.active {
                ev.active = active;
                changed = true;
            }
            if active && !matches!(ev.kind, ResolvedKind::PoolOffline) {
                any_transient = true;
            }
        }
        if any_transient {
            self.throttled_epochs += 1;
        }
        if changed {
            self.rebuild_overlay();
            self.revision += 1;
        }
        changed
    }

    fn rebuild_overlay(&mut self) {
        self.overlay.extra_rd_add.iter_mut().for_each(|v| *v = 0.0);
        self.overlay.extra_wr_add.iter_mut().for_each(|v| *v = 0.0);
        self.overlay.bw_scale.iter_mut().for_each(|v| *v = 1.0);
        self.storm_rd.iter_mut().for_each(|v| *v = 0.0);
        self.storm_wr.iter_mut().for_each(|v| *v = 0.0);
        let mut any = false;
        for ev in &self.events {
            if !ev.active {
                continue;
            }
            match &ev.kind {
                ResolvedKind::RetryStorm { rd, wr } => {
                    self.overlay.extra_rd_add[ev.pool] += rd;
                    self.overlay.extra_wr_add[ev.pool] += wr;
                    self.storm_rd[ev.pool] += rd;
                    self.storm_wr[ev.pool] += wr;
                    any = true;
                }
                ResolvedKind::LinkRetrain { frac, rows } => {
                    for &s in rows {
                        self.overlay.bw_scale[s] *= frac;
                    }
                    any = true;
                }
                ResolvedKind::PoolOffline => {}
            }
        }
        self.overlay_active = any;
    }

    /// The overlay the analyzer should run this epoch under, or `None`
    /// when every modifier is identity (the fault-free fast path).
    pub fn overlay(&self) -> Option<&FaultOverlay> {
        if self.overlay_active {
            Some(&self.overlay)
        } else {
            None
        }
    }

    /// Current overlay revision (monotonic; bumped on membership edges).
    pub fn revision(&self) -> u64 {
        self.revision
    }

    /// Exact retry-storm latency this epoch, from post-injection
    /// `[P, B]` read/write totals: stage 1 of the analyzer is a linear
    /// dot product, so the storm's share of `lat` is recoverable in
    /// closed form independent of epoch grouping or thread count.
    pub fn storm_delay_ns(
        &self,
        read_count: impl Fn(PoolId) -> f64,
        write_count: impl Fn(PoolId) -> f64,
    ) -> f64 {
        if !self.overlay_active {
            return 0.0;
        }
        let mut d = 0.0f64;
        for p in 0..self.storm_rd.len() {
            let (rd, wr) = (self.storm_rd[p] as f64, self.storm_wr[p] as f64);
            if rd != 0.0 {
                d += read_count(p) * rd;
            }
            if wr != 0.0 {
                d += write_count(p) * wr;
            }
        }
        d
    }

    /// Lowest-numbered online pool other than `from` (CXL pools first,
    /// then local DRAM), or the structured no-pool error.
    pub fn fallback_pool(&self, from: PoolId) -> Result<PoolId, FaultError> {
        for p in (1..self.offline.len()).chain(std::iter::once(0)) {
            if p != from && !self.offline[p] {
                return Ok(p);
            }
        }
        Err(FaultError::NoReachablePool)
    }

    /// Pools that are offline and may still hold live bytes (checked by
    /// the caller against the tracker's per-pool byte accounting).
    pub fn any_offline(&self) -> bool {
        self.pools_offline > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::builtin;

    #[test]
    fn inline_roundtrip_and_kinds() {
        let p = FaultPlan::parse_inline(
            "storm:pool1@5+10:rd=200,wr=300;retrain:pool0@8+4:frac=0.5;offline:direct0@12",
        )
        .unwrap();
        assert_eq!(p.events.len(), 3);
        assert_eq!(
            p.events[0].kind,
            FaultKind::RetryStorm { rd_add_ns: 200.0, wr_add_ns: 300.0 }
        );
        assert_eq!(p.events[1].kind, FaultKind::LinkRetrain { frac: 0.5 });
        assert_eq!(p.events[2], FaultSpec {
            pool: "direct0".into(),
            start: 12,
            epochs: 0,
            kind: FaultKind::PoolOffline
        });
    }

    #[test]
    fn toml_plan_parses() {
        let src = r#"
seed = 7
[[fault]]
kind = "storm"
pool = "pool1"
start = 2
epochs = 3
rd_add_ns = 150
[[fault]]
kind = "offline"
pool = "pool0"
start = 4
"#;
        let p = FaultPlan::parse_toml(src).unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.events.len(), 2);
        assert!(p.resolve(&builtin::fig2()).is_ok());
    }

    #[test]
    fn resolve_rejects_bad_specs() {
        let topo = builtin::fig2();
        let unknown = FaultPlan::parse_inline("storm:nosuch@1+2:rd=10").unwrap();
        assert!(matches!(unknown.resolve(&topo), Err(FaultError::UnknownPool(_))));
        let zero = FaultPlan::parse_inline("storm:pool1@1+0:rd=10").unwrap();
        assert!(matches!(zero.resolve(&topo), Err(FaultError::ZeroWindow(_))));
        let overlap =
            FaultPlan::parse_inline("offline:pool1@1;offline:pool1@5").unwrap();
        assert!(matches!(overlap.resolve(&topo), Err(FaultError::OverlappingOffline(_))));
        let badfrac = FaultPlan::parse_inline("retrain:pool1@1+2:frac=1.5");
        assert!(matches!(badfrac, Err(FaultError::Parse(_))));
    }

    #[test]
    fn windows_activate_and_expire_with_revision_edges() {
        let topo = builtin::fig2();
        let plan = FaultPlan::parse_inline("storm:pool1@2+3:rd=100;offline:pool0@4").unwrap();
        let mut st = plan.resolve(&topo).unwrap();
        assert!(!st.epoch_begin(0));
        assert!(st.overlay().is_none());
        assert!(st.epoch_begin(2)); // storm opens
        let ov = st.overlay().unwrap();
        assert_eq!(ov.extra_rd_add[1], 100.0);
        assert!(!st.epoch_begin(3)); // still open, no edge
        assert!(st.epoch_begin(4)); // offline fires (edge), storm still open
        assert!(st.offline[1]); // pool0 is PoolId 1 in fig2
        assert_eq!(st.pools_offline, 1);
        assert!(st.epoch_begin(5)); // storm expires
        assert!(st.overlay().is_none(), "offline alone leaves the overlay identity");
        assert_eq!(st.faults_injected, 2);
        assert_eq!(st.throttled_epochs, 3); // epochs 2,3,4
    }

    #[test]
    fn retrain_scales_path_rows_only() {
        let topo = builtin::fig2();
        // pool0 (PoolId 1) routes through sw0 and rc0 in fig2;
        // direct0 (PoolId 3) routes through rc0 only.
        let plan = FaultPlan::parse_inline("retrain:pool0@0+2:frac=0.25").unwrap();
        let mut st = plan.resolve(&topo).unwrap();
        st.epoch_begin(0);
        let ov = st.overlay().unwrap();
        let scaled: Vec<usize> =
            (0..ov.bw_scale.len()).filter(|&s| ov.bw_scale[s] != 1.0).collect();
        for &s in &scaled {
            assert!(topo.routes_through(1, topo.switch_nodes()[s]));
            assert_eq!(ov.bw_scale[s], 0.25);
        }
        assert!(!scaled.is_empty());
    }

    #[test]
    fn fallback_prefers_low_cxl_pool_then_local() {
        let topo = builtin::fig2();
        let plan = FaultPlan::parse_inline("offline:pool0@0").unwrap();
        let mut st = plan.resolve(&topo).unwrap();
        st.epoch_begin(0);
        assert_eq!(st.fallback_pool(1).unwrap(), 2); // pool1
        st.offline[2] = true;
        st.offline[3] = true;
        assert_eq!(st.fallback_pool(1).unwrap(), 0); // local DRAM last
        st.offline[0] = true;
        assert!(matches!(st.fallback_pool(1), Err(FaultError::NoReachablePool)));
    }

    #[test]
    fn seeded_jitter_is_deterministic_and_bounded() {
        let topo = builtin::fig2();
        let mut plan = FaultPlan::parse_inline("storm:pool1@10+2:rd=5").unwrap();
        plan.seed = 99;
        plan.jitter_epochs = 4;
        let a = plan.resolve(&topo).unwrap();
        let b = plan.resolve(&topo).unwrap();
        assert_eq!(a.events[0].start, b.events[0].start);
        assert!(a.events[0].start >= 10 && a.events[0].start <= 14);
    }

    #[test]
    fn numeric_pool_ids_accepted() {
        let topo = builtin::fig2();
        let plan = FaultPlan::parse_inline("storm:2@1+2:rd=5").unwrap();
        let st = plan.resolve(&topo).unwrap();
        assert_eq!(st.events[0].pool, 2);
        assert!(FaultPlan::parse_inline("storm:9@1+2:rd=5")
            .unwrap()
            .resolve(&topo)
            .is_err());
    }
}

//! Workload engine — the "attached, unmodified program" substitute.
//!
//! CXLMemSim attaches to arbitrary programs; here, deterministic
//! synthetic twins of the paper's benchmarks emit the exact event
//! stream (allocation syscalls + memory accesses) that eBPF + the CPU
//! would produce. §4's benchmarks are reproduced by name:
//!
//!   * five allocation microbenchmarks (`mmap_read`, `mmap_write`,
//!     `sbrk`, `malloc`, `calloc`) — allocate via different interfaces,
//!     then sweep the region sequentially (paper: "perform sequential
//!     writes to the allocated memory"; `mmap_read` reads);
//!   * `mcf_like` — SPEC2017 mcf's dominant pattern: pointer chasing
//!     over a network-simplex graph with poor locality;
//!   * `wrf_like` — SPEC2017 wrf's dominant pattern: 3-D stencil sweeps
//!     over a large grid with streaming locality.
//!
//! Working sets default to the paper's (100 MB micro, 10 GB calloc) and
//! scale with `--scale` so tests stay fast.

pub mod mcf_like;
pub mod micro;
pub mod patterns;
pub mod wrf_like;

use crate::trace::WlEvent;

/// A deterministic program that emits events one at a time.
pub trait Workload: Send {
    fn name(&self) -> &str;
    /// Next event in program order; None when the program exits.
    fn next_event(&mut self) -> Option<WlEvent>;
    /// Rough total number of accesses (progress reporting only).
    fn total_accesses_hint(&self) -> u64;
}

/// Pull up to `budget` events into `sink`; returns false if finished.
pub fn advance<W: Workload + ?Sized>(
    wl: &mut W,
    budget: usize,
    sink: &mut dyn FnMut(WlEvent),
) -> bool {
    for _ in 0..budget {
        match wl.next_event() {
            Some(ev) => sink(ev),
            None => return false,
        }
    }
    true
}

/// The paper's Table-1 benchmark list, in row order.
pub const TABLE1_WORKLOADS: &[&str] = &[
    "mmap_read",
    "mmap_write",
    "sbrk",
    "malloc",
    "calloc",
    "mcf_like",
    "wrf_like",
];

/// Construct a workload by name. `scale` in (0, 1] shrinks working sets
/// (1.0 = the paper's sizes); `seed` drives any randomized structure.
pub fn by_name(name: &str, scale: f64, seed: u64) -> Option<Box<dyn Workload>> {
    let scale = scale.clamp(1e-6, 1.0);
    Some(match name {
        "mmap_read" => Box::new(micro::MicroBench::mmap_read(scale)),
        "mmap_write" => Box::new(micro::MicroBench::mmap_write(scale)),
        "sbrk" => Box::new(micro::MicroBench::sbrk(scale)),
        "malloc" => Box::new(micro::MicroBench::malloc(scale)),
        "calloc" => Box::new(micro::MicroBench::calloc(scale)),
        "mcf_like" => Box::new(mcf_like::McfLike::new(scale, seed)),
        "wrf_like" => Box::new(wrf_like::WrfLike::new(scale)),
        "uniform" => Box::new(patterns::PatternWorkload::uniform(scale, seed)),
        "zipfian" => Box::new(patterns::PatternWorkload::zipfian(scale, seed)),
        "stream" => Box::new(patterns::PatternWorkload::stream(scale)),
        "shared" => Box::new(patterns::PatternWorkload::shared(scale, seed, 0.3)),
        _ => return None,
    })
}

/// Replay a recorded trace (`cxlmemsim record` / `trace::io`) as a
/// workload — lets one capture be simulated against many topologies.
pub struct TraceReplay {
    name: String,
    events: std::vec::IntoIter<WlEvent>,
    total: u64,
}

impl TraceReplay {
    pub fn new(name: &str, events: Vec<WlEvent>) -> TraceReplay {
        let total = events
            .iter()
            .filter(|e| matches!(e, WlEvent::Access(_)))
            .count() as u64;
        TraceReplay { name: name.to_string(), events: events.into_iter(), total }
    }
}

impl Workload for TraceReplay {
    fn name(&self) -> &str {
        &self.name
    }
    fn next_event(&mut self) -> Option<WlEvent> {
        self.events.next()
    }
    fn total_accesses_hint(&self) -> u64 {
        self.total
    }
}

pub const ALL_WORKLOADS: &[&str] = &[
    "mmap_read",
    "mmap_write",
    "sbrk",
    "malloc",
    "calloc",
    "mcf_like",
    "wrf_like",
    "uniform",
    "zipfian",
    "stream",
    "shared",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::WlEvent;

    #[test]
    fn all_workloads_construct_and_emit() {
        for name in ALL_WORKLOADS {
            let mut wl = by_name(name, 0.001, 7).unwrap_or_else(|| panic!("{name}"));
            let mut alloc = 0;
            let mut access = 0;
            for _ in 0..10_000 {
                match wl.next_event() {
                    Some(WlEvent::Alloc(_)) => alloc += 1,
                    Some(WlEvent::Access(_)) => access += 1,
                    None => break,
                }
            }
            assert!(alloc > 0, "{name} never allocated");
            assert!(access > 0, "{name} never accessed memory");
        }
    }

    #[test]
    fn workloads_terminate_at_tiny_scale() {
        for name in ALL_WORKLOADS {
            let mut wl = by_name(name, 0.0005, 7).unwrap();
            let mut n = 0u64;
            while wl.next_event().is_some() {
                n += 1;
                assert!(n < 80_000_000, "{name} too long at tiny scale");
            }
            assert!(n > 0);
        }
    }

    #[test]
    fn workloads_are_deterministic() {
        for name in ["mcf_like", "uniform", "zipfian"] {
            let mut a = by_name(name, 0.001, 42).unwrap();
            let mut b = by_name(name, 0.001, 42).unwrap();
            for _ in 0..5000 {
                match (a.next_event(), b.next_event()) {
                    (Some(WlEvent::Access(x)), Some(WlEvent::Access(y))) => {
                        assert_eq!(x.addr, y.addr, "{name}");
                        assert_eq!(x.is_write, y.is_write);
                    }
                    (Some(WlEvent::Alloc(x)), Some(WlEvent::Alloc(y))) => {
                        assert_eq!(x.addr, y.addr);
                        assert_eq!(x.len, y.len);
                    }
                    (None, None) => break,
                    _ => panic!("{name} diverged"),
                }
            }
        }
    }

    #[test]
    fn seeds_change_random_workloads() {
        let mut a = by_name("uniform", 0.001, 1).unwrap();
        let mut b = by_name("uniform", 0.001, 2).unwrap();
        let mut differs = false;
        for _ in 0..2000 {
            match (a.next_event(), b.next_event()) {
                (Some(WlEvent::Access(x)), Some(WlEvent::Access(y))) => {
                    if x.addr != y.addr {
                        differs = true;
                        break;
                    }
                }
                _ => {}
            }
        }
        assert!(differs);
    }

    #[test]
    fn unknown_workload_is_none() {
        assert!(by_name("quake3", 1.0, 0).is_none());
    }

    #[test]
    fn advance_respects_budget() {
        let mut wl = by_name("stream", 0.01, 0).unwrap();
        let mut n = 0;
        let more = advance(wl.as_mut(), 100, &mut |_| n += 1);
        assert!(more);
        assert_eq!(n, 100);
    }
}

//! Workload engine — the "attached, unmodified program" substitute.
//!
//! CXLMemSim attaches to arbitrary programs; here, deterministic
//! synthetic twins of the paper's benchmarks emit the exact event
//! stream (allocation syscalls + memory accesses) that eBPF + the CPU
//! would produce. §4's benchmarks are reproduced by name:
//!
//!   * five allocation microbenchmarks (`mmap_read`, `mmap_write`,
//!     `sbrk`, `malloc`, `calloc`) — allocate via different interfaces,
//!     then sweep the region sequentially (paper: "perform sequential
//!     writes to the allocated memory"; `mmap_read` reads);
//!   * `mcf_like` — SPEC2017 mcf's dominant pattern: pointer chasing
//!     over a network-simplex graph with poor locality;
//!   * `wrf_like` — SPEC2017 wrf's dominant pattern: 3-D stencil sweeps
//!     over a large grid with streaming locality.
//!
//! Working sets default to the paper's (100 MB micro, 10 GB calloc) and
//! scale with `--scale` so tests stay fast.

pub mod mcf_like;
pub mod micro;
pub mod patterns;
pub mod wrf_like;

use crate::trace::WlEvent;

/// A deterministic program that emits events in program order.
///
/// `next_event` is the one-at-a-time interface; the hot path is
/// [`Workload::next_batch`], which lets an implementation emit a run of
/// events through one virtual call so the coordinator's inner loop
/// stays monomorphic. Implementations MUST emit the exact same event
/// sequence through both interfaces (asserted per-module in tests and
/// end-to-end in `tests/pipeline_equivalence.rs`).
pub trait Workload: Send {
    fn name(&self) -> &str;
    /// Next event in program order; None when the program exits.
    fn next_event(&mut self) -> Option<WlEvent>;
    /// Append up to `budget` events (in program order) to `sink`;
    /// returns false once the program has exited. The default
    /// delegates to `next_event`; the built-in workloads override it
    /// with native run-length emission.
    ///
    /// Contract: for `budget > 0`, an implementation must either push
    /// at least one event or return false — a `true` return with
    /// nothing pushed would stall consumers (the epoch driver
    /// debug-asserts against it; multihost treats it as exhaustion).
    fn next_batch(&mut self, sink: &mut Vec<WlEvent>, budget: usize) -> bool {
        for _ in 0..budget {
            match self.next_event() {
                Some(ev) => sink.push(ev),
                None => return false,
            }
        }
        true
    }
    /// Rough total number of accesses (progress reporting only).
    fn total_accesses_hint(&self) -> u64;
}

/// Pull up to `budget` events into `sink`; returns false if finished.
pub fn advance<W: Workload + ?Sized>(
    wl: &mut W,
    budget: usize,
    sink: &mut dyn FnMut(WlEvent),
) -> bool {
    for _ in 0..budget {
        match wl.next_event() {
            Some(ev) => sink(ev),
            None => return false,
        }
    }
    true
}

/// Drain a workload completely through the batched interface, counting
/// events without storing them (bench/baseline helper).
pub fn drain_batched<W: Workload + ?Sized>(wl: &mut W, batch: usize) -> u64 {
    let mut buf: Vec<WlEvent> = Vec::with_capacity(batch.max(1));
    let mut n = 0u64;
    loop {
        buf.clear();
        let more = wl.next_batch(&mut buf, batch.max(1));
        n += buf.len() as u64;
        if !more {
            return n;
        }
    }
}

/// Assert that `a` (drained per-event) and `b` (drained batched with
/// `batch`) emit identical event streams. Test helper shared by the
/// per-module equivalence tests.
pub fn assert_same_stream(a: &mut dyn Workload, b: &mut dyn Workload, batch: usize) {
    let mut bbuf: Vec<WlEvent> = Vec::new();
    let mut i = 0usize;
    let mut b_done = false;
    let mut n = 0u64;
    loop {
        if i >= bbuf.len() {
            if b_done {
                assert!(a.next_event().is_none(), "batched stream ended early at {n}");
                return;
            }
            bbuf.clear();
            i = 0;
            b_done = !b.next_batch(&mut bbuf, batch);
            continue;
        }
        let ev_b = bbuf[i];
        i += 1;
        let ev_a = a.next_event().unwrap_or_else(|| panic!("per-event stream ended early at {n}"));
        match (ev_a, ev_b) {
            (WlEvent::Access(x), WlEvent::Access(y)) => {
                assert_eq!(x.addr, y.addr, "access addr diverged at {n}");
                assert_eq!(x.is_write, y.is_write, "access rw diverged at {n}");
            }
            (WlEvent::Alloc(x), WlEvent::Alloc(y)) => {
                assert_eq!(x.addr, y.addr, "alloc addr diverged at {n}");
                assert_eq!(x.len, y.len, "alloc len diverged at {n}");
                assert_eq!(x.kind, y.kind, "alloc kind diverged at {n}");
            }
            _ => panic!("event kind diverged at {n}"),
        }
        n += 1;
    }
}

/// The paper's Table-1 benchmark list, in row order.
pub const TABLE1_WORKLOADS: &[&str] = &[
    "mmap_read",
    "mmap_write",
    "sbrk",
    "malloc",
    "calloc",
    "mcf_like",
    "wrf_like",
];

/// Construct a workload by name. `scale` in (0, 1] shrinks working sets
/// (1.0 = the paper's sizes); `seed` drives any randomized structure.
pub fn by_name(name: &str, scale: f64, seed: u64) -> Option<Box<dyn Workload>> {
    let scale = scale.clamp(1e-6, 1.0);
    Some(match name {
        "mmap_read" => Box::new(micro::MicroBench::mmap_read(scale)),
        "mmap_write" => Box::new(micro::MicroBench::mmap_write(scale)),
        "sbrk" => Box::new(micro::MicroBench::sbrk(scale)),
        "malloc" => Box::new(micro::MicroBench::malloc(scale)),
        "calloc" => Box::new(micro::MicroBench::calloc(scale)),
        "mcf_like" => Box::new(mcf_like::McfLike::new(scale, seed)),
        "wrf_like" => Box::new(wrf_like::WrfLike::new(scale)),
        "uniform" => Box::new(patterns::PatternWorkload::uniform(scale, seed)),
        "zipfian" => Box::new(patterns::PatternWorkload::zipfian(scale, seed)),
        "stream" => Box::new(patterns::PatternWorkload::stream(scale)),
        "shared" => Box::new(patterns::PatternWorkload::shared(scale, seed, 0.3)),
        _ => return None,
    })
}

/// Replay a recorded trace (`cxlmemsim record` / `trace::io`) as a
/// workload — lets one capture be simulated against many topologies.
pub struct TraceReplay {
    name: String,
    events: std::vec::IntoIter<WlEvent>,
    total: u64,
}

impl TraceReplay {
    pub fn new(name: &str, events: Vec<WlEvent>) -> TraceReplay {
        let total = events
            .iter()
            .filter(|e| matches!(e, WlEvent::Access(_)))
            .count() as u64;
        TraceReplay { name: name.to_string(), events: events.into_iter(), total }
    }
}

impl Workload for TraceReplay {
    fn name(&self) -> &str {
        &self.name
    }
    fn next_event(&mut self) -> Option<WlEvent> {
        self.events.next()
    }
    fn next_batch(&mut self, sink: &mut Vec<WlEvent>, budget: usize) -> bool {
        let before = sink.len();
        sink.extend(self.events.by_ref().take(budget));
        sink.len() - before == budget
    }
    fn total_accesses_hint(&self) -> u64 {
        self.total
    }
}

/// A recorded trace opened as a workload, auto-detected by magic:
/// CXLTRC v2 streams through [`crate::trace::stream::TraceStream`]
/// with O(chunk) memory; v1 and JSONL (which have no chunk directory)
/// load fully into a [`TraceReplay`]. Both replay the identical event
/// sequence, so reports are bit-identical across the formats.
pub enum TraceWorkload {
    Memory(TraceReplay),
    Stream(crate::trace::stream::TraceStream),
}

impl TraceWorkload {
    pub fn open(path: &str) -> anyhow::Result<TraceWorkload> {
        use crate::trace::io::{self as tio, TraceFormat};
        let mut head = [0u8; 8];
        let n = {
            use std::io::Read;
            let mut f =
                std::fs::File::open(path).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            f.read(&mut head).map_err(|e| anyhow::anyhow!("{path}: {e}"))?
        };
        let events = match tio::detect_format(&head[..n]) {
            TraceFormat::V2 => {
                let s = crate::trace::stream::TraceStream::open(path)
                    .map_err(|e| anyhow::anyhow!(e))?;
                return Ok(TraceWorkload::Stream(s));
            }
            TraceFormat::V1 => {
                let bytes = std::fs::read(path)?;
                tio::read_binary(&bytes).map_err(|e| anyhow::anyhow!("{path}: {e}"))?
            }
            TraceFormat::Jsonl => tio::read_jsonl(std::fs::File::open(path)?)
                .map_err(|e| anyhow::anyhow!("{path}: {e}"))?,
        };
        Ok(TraceWorkload::Memory(TraceReplay::new(&format!("replay:{path}"), events)))
    }

    /// Open shard `i` of `n` (0-based) for sharded replay. Only the
    /// CXLTRC v2 format can shard — its chunk directory makes the
    /// shard's first chunk an O(1) seek; v1 and JSONL traces have no
    /// directory, so asking for a shard of one is a structured error
    /// rather than a silent full replay.
    pub fn open_shard(path: &str, i: usize, n: usize) -> anyhow::Result<TraceWorkload> {
        use crate::trace::io::{self as tio, TraceFormat};
        let mut head = [0u8; 8];
        let len = {
            use std::io::Read;
            let mut f =
                std::fs::File::open(path).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            f.read(&mut head).map_err(|e| anyhow::anyhow!("{path}: {e}"))?
        };
        match tio::detect_format(&head[..len]) {
            TraceFormat::V2 => {
                let s = crate::trace::stream::TraceStream::open_shard(path, i, n)
                    .map_err(|e| anyhow::anyhow!(e))?;
                Ok(TraceWorkload::Stream(s))
            }
            TraceFormat::V1 => anyhow::bail!(
                "{path}: sharded replay (--shard) requires a CXLTRC v2 trace; this is a \
                 v1 trace with no chunk directory to seek — re-record it, or convert by \
                 replaying through `record`"
            ),
            TraceFormat::Jsonl => anyhow::bail!(
                "{path}: sharded replay (--shard) requires a CXLTRC v2 trace; this is a \
                 JSONL trace with no chunk directory to seek — re-record it with the \
                 binary format"
            ),
        }
    }

    /// A decode error surfaced mid-stream (streaming replay ends early
    /// on a damaged chunk); callers must check this after the run.
    pub fn take_error(&mut self) -> Option<String> {
        match self {
            TraceWorkload::Stream(s) => s.take_error(),
            TraceWorkload::Memory(_) => None,
        }
    }

    /// The underlying stream, when the trace opened in streaming mode.
    pub fn stream(&self) -> Option<&crate::trace::stream::TraceStream> {
        match self {
            TraceWorkload::Stream(s) => Some(s),
            TraceWorkload::Memory(_) => None,
        }
    }
}

impl Workload for TraceWorkload {
    fn name(&self) -> &str {
        match self {
            TraceWorkload::Memory(r) => r.name(),
            TraceWorkload::Stream(s) => s.name(),
        }
    }
    fn next_event(&mut self) -> Option<WlEvent> {
        match self {
            TraceWorkload::Memory(r) => r.next_event(),
            TraceWorkload::Stream(s) => s.next_event(),
        }
    }
    fn next_batch(&mut self, sink: &mut Vec<WlEvent>, budget: usize) -> bool {
        match self {
            TraceWorkload::Memory(r) => r.next_batch(sink, budget),
            TraceWorkload::Stream(s) => s.next_batch(sink, budget),
        }
    }
    fn total_accesses_hint(&self) -> u64 {
        match self {
            TraceWorkload::Memory(r) => r.total_accesses_hint(),
            TraceWorkload::Stream(s) => s.total_accesses_hint(),
        }
    }
}

pub const ALL_WORKLOADS: &[&str] = &[
    "mmap_read",
    "mmap_write",
    "sbrk",
    "malloc",
    "calloc",
    "mcf_like",
    "wrf_like",
    "uniform",
    "zipfian",
    "stream",
    "shared",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::WlEvent;

    #[test]
    fn all_workloads_construct_and_emit() {
        for name in ALL_WORKLOADS {
            let mut wl = by_name(name, 0.001, 7).unwrap_or_else(|| panic!("{name}"));
            let mut alloc = 0;
            let mut access = 0;
            for _ in 0..10_000 {
                match wl.next_event() {
                    Some(WlEvent::Alloc(_)) => alloc += 1,
                    Some(WlEvent::Access(_)) => access += 1,
                    None => break,
                }
            }
            assert!(alloc > 0, "{name} never allocated");
            assert!(access > 0, "{name} never accessed memory");
        }
    }

    #[test]
    fn workloads_terminate_at_tiny_scale() {
        for name in ALL_WORKLOADS {
            let mut wl = by_name(name, 0.0005, 7).unwrap();
            let mut n = 0u64;
            while wl.next_event().is_some() {
                n += 1;
                assert!(n < 80_000_000, "{name} too long at tiny scale");
            }
            assert!(n > 0);
        }
    }

    #[test]
    fn workloads_are_deterministic() {
        for name in ["mcf_like", "uniform", "zipfian"] {
            let mut a = by_name(name, 0.001, 42).unwrap();
            let mut b = by_name(name, 0.001, 42).unwrap();
            for _ in 0..5000 {
                match (a.next_event(), b.next_event()) {
                    (Some(WlEvent::Access(x)), Some(WlEvent::Access(y))) => {
                        assert_eq!(x.addr, y.addr, "{name}");
                        assert_eq!(x.is_write, y.is_write);
                    }
                    (Some(WlEvent::Alloc(x)), Some(WlEvent::Alloc(y))) => {
                        assert_eq!(x.addr, y.addr);
                        assert_eq!(x.len, y.len);
                    }
                    (None, None) => break,
                    _ => panic!("{name} diverged"),
                }
            }
        }
    }

    #[test]
    fn seeds_change_random_workloads() {
        let mut a = by_name("uniform", 0.001, 1).unwrap();
        let mut b = by_name("uniform", 0.001, 2).unwrap();
        let mut differs = false;
        for _ in 0..2000 {
            match (a.next_event(), b.next_event()) {
                (Some(WlEvent::Access(x)), Some(WlEvent::Access(y))) => {
                    if x.addr != y.addr {
                        differs = true;
                        break;
                    }
                }
                _ => {}
            }
        }
        assert!(differs);
    }

    #[test]
    fn unknown_workload_is_none() {
        assert!(by_name("quake3", 1.0, 0).is_none());
    }

    #[test]
    fn advance_respects_budget() {
        let mut wl = by_name("stream", 0.01, 0).unwrap();
        let mut n = 0;
        let more = advance(wl.as_mut(), 100, &mut |_| n += 1);
        assert!(more);
        assert_eq!(n, 100);
    }

    #[test]
    fn next_batch_respects_budget_and_termination() {
        let mut wl = by_name("stream", 0.001, 0).unwrap();
        let mut buf = Vec::new();
        assert!(wl.next_batch(&mut buf, 64));
        assert_eq!(buf.len(), 64);
        // drain the remainder; the final pull must report exhaustion
        let rest = drain_batched(wl.as_mut(), 4096);
        assert!(rest > 0);
        let mut buf = Vec::new();
        assert!(!wl.next_batch(&mut buf, 16));
        assert!(buf.is_empty());
    }

    #[test]
    fn batched_stream_identical_for_every_workload() {
        for name in ALL_WORKLOADS {
            for batch in [1usize, 7, 1024] {
                let mut a = by_name(name, 0.0008, 11).unwrap();
                let mut b = by_name(name, 0.0008, 11).unwrap();
                assert_same_stream(a.as_mut(), b.as_mut(), batch);
            }
        }
    }

    #[test]
    fn trace_workload_auto_detects_all_three_formats() {
        use crate::trace::io as tio;
        let mut src = by_name("sbrk", 0.002, 5).unwrap();
        let mut events = Vec::new();
        while let Some(ev) = src.next_event() {
            events.push(ev);
            if events.len() >= 400 {
                break;
            }
        }
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let v1 = dir.join(format!("cxlms-auto-{pid}.v1"));
        let v2 = dir.join(format!("cxlms-auto-{pid}.v2"));
        let jl = dir.join(format!("cxlms-auto-{pid}.jsonl"));
        let mut f = std::fs::File::create(&v1).unwrap();
        tio::write_binary(&mut f, &events).unwrap();
        let mut f = std::fs::File::create(&v2).unwrap();
        tio::write_binary_v2_chunked(&mut f, &events, 64).unwrap();
        let mut f = std::fs::File::create(&jl).unwrap();
        tio::write_jsonl(&mut f, &events).unwrap();
        for (path, want_stream) in [(&v1, false), (&v2, true), (&jl, false)] {
            let mut wl = TraceWorkload::open(path.to_str().unwrap()).unwrap();
            assert_eq!(wl.stream().is_some(), want_stream, "{path:?}");
            let n = drain_batched(&mut wl, 77);
            assert_eq!(n as usize, events.len(), "{path:?}");
            assert!(wl.take_error().is_none());
        }
        for p in [&v1, &v2, &jl] {
            std::fs::remove_file(p).ok();
        }
        assert!(TraceWorkload::open("/does/not/exist.bin").is_err());
    }

    #[test]
    fn trace_replay_batched_matches_per_event() {
        let mut src = by_name("sbrk", 0.002, 1).unwrap();
        let mut events = Vec::new();
        while let Some(ev) = src.next_event() {
            events.push(ev);
        }
        let mut a = TraceReplay::new("r", events.clone());
        let mut b = TraceReplay::new("r", events);
        assert_same_stream(&mut a, &mut b, 33);
    }
}

//! `wrf_like`: SPEC2017 521.wrf's dominant memory behaviour.
//!
//! WRF (weather research & forecasting) advances coupled PDEs over a
//! 3-D grid; memory-wise it is streaming stencil sweeps: for each cell,
//! read the 6 face neighbours + itself, write the result. Cells carry
//! multiple physics fields, so one cell ≈ one 64 B cacheline. This twin
//! sweeps a `dim³` grid (~128 MB at scale 1.0, comfortably past the
//! 30 MB LLC) for a few timesteps.

use crate::trace::{Access, AllocEvent, AllocKind, WlEvent};

use super::Workload;

const LINE: u64 = 64;
const GRID_BASE: u64 = 0x7f30_0000_0000;
const SWEEPS: u64 = 2;

enum Phase {
    Alloc,
    Run,
    Done,
}

pub struct WrfLike {
    dim: u64,
    phase: Phase,
    sweep: u64,
    cell: u64,
    /// 0..=6: neighbour read index within the current cell (6 = center),
    /// 7 = write-back of the result.
    micro_step: u64,
}

impl WrfLike {
    pub fn new(scale: f64) -> WrfLike {
        // dim^3 cells * 64B; scale 1.0 -> dim 128 -> 128 MB
        let dim = ((128.0 * scale.powf(1.0 / 3.0)) as u64).max(4);
        WrfLike { dim, phase: Phase::Alloc, sweep: 0, cell: 0, micro_step: 0 }
    }

    fn cells(&self) -> u64 {
        self.dim * self.dim * self.dim
    }

    fn grid_bytes(&self) -> u64 {
        self.cells() * LINE
    }

    #[inline]
    fn addr_of(&self, cell: u64) -> u64 {
        GRID_BASE + cell * LINE
    }

    /// Neighbour cell index for micro_step 0..6 (clamped at faces).
    #[inline]
    fn neighbour(&self, cell: u64, step: u64) -> u64 {
        let d = self.dim;
        let x = cell % d;
        let y = (cell / d) % d;
        let z = cell / (d * d);
        let (nx, ny, nz) = match step {
            0 => (x.saturating_sub(1), y, z),
            1 => ((x + 1).min(d - 1), y, z),
            2 => (x, y.saturating_sub(1), z),
            3 => (x, (y + 1).min(d - 1), z),
            4 => (x, y, z.saturating_sub(1)),
            5 => (x, y, (z + 1).min(d - 1)),
            _ => (x, y, z),
        };
        nx + ny * d + nz * d * d
    }
}

impl Workload for WrfLike {
    fn name(&self) -> &str {
        "wrf_like"
    }

    fn next_event(&mut self) -> Option<WlEvent> {
        loop {
            match self.phase {
                Phase::Alloc => {
                    self.phase = Phase::Run;
                    return Some(WlEvent::Alloc(AllocEvent {
                        kind: AllocKind::Mmap,
                        addr: GRID_BASE,
                        len: self.grid_bytes(),
                        t_ns: 2_000.0,
                    }));
                }
                Phase::Run => {
                    if self.sweep >= SWEEPS {
                        self.phase = Phase::Done;
                        continue;
                    }
                    let ev = if self.micro_step < 7 {
                        let n = self.neighbour(self.cell, self.micro_step);
                        WlEvent::Access(Access { addr: self.addr_of(n), is_write: false })
                    } else {
                        WlEvent::Access(Access { addr: self.addr_of(self.cell), is_write: true })
                    };
                    self.micro_step += 1;
                    if self.micro_step > 7 {
                        self.micro_step = 0;
                        self.cell += 1;
                        if self.cell >= self.cells() {
                            self.cell = 0;
                            self.sweep += 1;
                        }
                    }
                    return Some(ev);
                }
                Phase::Done => return None,
            }
        }
    }

    /// Native batched emission: the 8-step stencil micro-loop runs
    /// inside one monomorphic loop per batch. Emits the exact sequence
    /// `next_event` would.
    fn next_batch(&mut self, sink: &mut Vec<WlEvent>, budget: usize) -> bool {
        let mut left = budget as u64;
        while left > 0 {
            match self.phase {
                Phase::Alloc => {
                    self.phase = Phase::Run;
                    sink.push(WlEvent::Alloc(AllocEvent {
                        kind: AllocKind::Mmap,
                        addr: GRID_BASE,
                        len: self.grid_bytes(),
                        t_ns: 2_000.0,
                    }));
                    left -= 1;
                }
                Phase::Run => {
                    if self.sweep >= SWEEPS {
                        self.phase = Phase::Done;
                        continue;
                    }
                    let cells = self.cells();
                    while left > 0 {
                        let ev = if self.micro_step < 7 {
                            let n = self.neighbour(self.cell, self.micro_step);
                            WlEvent::Access(Access { addr: self.addr_of(n), is_write: false })
                        } else {
                            WlEvent::Access(Access {
                                addr: self.addr_of(self.cell),
                                is_write: true,
                            })
                        };
                        sink.push(ev);
                        left -= 1;
                        self.micro_step += 1;
                        if self.micro_step > 7 {
                            self.micro_step = 0;
                            self.cell += 1;
                            if self.cell >= cells {
                                self.cell = 0;
                                self.sweep += 1;
                                if self.sweep >= SWEEPS {
                                    break;
                                }
                            }
                        }
                    }
                }
                Phase::Done => return false,
            }
        }
        true
    }

    fn total_accesses_hint(&self) -> u64 {
        self.cells() * 8 * SWEEPS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocates_grid_then_runs() {
        let mut wl = WrfLike::new(0.001);
        match wl.next_event().unwrap() {
            WlEvent::Alloc(a) => {
                assert_eq!(a.addr, GRID_BASE);
                assert_eq!(a.len, wl.grid_bytes());
            }
            _ => panic!("expected alloc first"),
        }
    }

    #[test]
    fn stencil_pattern_reads_then_writes() {
        let mut wl = WrfLike::new(0.001);
        wl.next_event(); // alloc
        let evs: Vec<_> = (0..8).map(|_| wl.next_event().unwrap()).collect();
        let reads = evs
            .iter()
            .filter(|e| matches!(e, WlEvent::Access(a) if !a.is_write))
            .count();
        let writes = evs
            .iter()
            .filter(|e| matches!(e, WlEvent::Access(a) if a.is_write))
            .count();
        assert_eq!(reads, 7);
        assert_eq!(writes, 1);
    }

    #[test]
    fn exact_event_count() {
        let mut wl = WrfLike::new(0.0008); // tiny grid
        let hint = wl.total_accesses_hint();
        let mut n = 0u64;
        let mut allocs = 0;
        while let Some(ev) = wl.next_event() {
            match ev {
                WlEvent::Alloc(_) => allocs += 1,
                WlEvent::Access(_) => n += 1,
            }
        }
        assert_eq!(allocs, 1);
        assert_eq!(n, hint);
    }

    #[test]
    fn neighbours_stay_in_grid() {
        let wl = WrfLike::new(0.002);
        let cells = wl.cells();
        for cell in [0, cells / 2, cells - 1] {
            for step in 0..7 {
                assert!(wl.neighbour(cell, step) < cells);
            }
        }
    }

    #[test]
    fn streaming_locality_is_high() {
        let mut wl = WrfLike::new(0.002);
        wl.next_event();
        let mut addrs = Vec::new();
        for _ in 0..8000 {
            if let Some(WlEvent::Access(a)) = wl.next_event() {
                addrs.push(a.addr);
            }
        }
        // most consecutive accesses are within a dim^2 plane stride
        let near = addrs
            .windows(2)
            .filter(|w| w[0].abs_diff(w[1]) <= wl.dim * wl.dim * LINE)
            .count();
        assert!(near as f64 / addrs.len() as f64 > 0.7);
    }

    #[test]
    fn batched_emission_identical() {
        use crate::workload::assert_same_stream;
        for batch in [1usize, 5, 512] {
            let mut a = WrfLike::new(0.001);
            let mut b = WrfLike::new(0.001);
            assert_same_stream(&mut a, &mut b, batch);
        }
    }

    #[test]
    fn scale_shrinks_dim_cubically() {
        assert_eq!(WrfLike::new(1.0).dim, 128);
        let d = WrfLike::new(1.0 / 8.0).dim;
        assert!((63..=64).contains(&d), "dim={d}");
    }
}

//! Parameterizable synthetic access patterns for characterization
//! experiments (topology sweeps, congestion studies, policy ablations):
//! uniform-random, zipfian-hot-set, and pure streaming.

use crate::trace::{Access, AllocEvent, AllocKind, WlEvent};
use crate::util::rng::{Rng, Zipf};

use super::Workload;

const LINE: u64 = 64;
const MB: u64 = 1 << 20;
const BASE: u64 = 0x7f40_0000_0000;

/// Address range shared by every host in a multihost simulation
/// (coherency studies): workloads built by [`PatternWorkload::shared`]
/// allocate and access this range, so peer writes back-invalidate.
pub const SHARED_BASE: u64 = 0x7f80_0000_0000;

enum Pattern {
    Uniform,
    Zipfian(Zipf),
    Stream,
}

pub struct PatternWorkload {
    name: &'static str,
    pattern: Pattern,
    bytes: u64,
    lines: u64,
    base: u64,
    accesses_left: u64,
    total: u64,
    write_ratio: f64,
    cursor: u64,
    rng: Rng,
    allocated: bool,
}

impl PatternWorkload {
    fn new(
        name: &'static str,
        pattern: Pattern,
        scale: f64,
        seed: u64,
        write_ratio: f64,
    ) -> PatternWorkload {
        let bytes = ((200.0 * scale) as u64).max(1) * MB;
        let lines = bytes / LINE;
        let total = lines * 4;
        PatternWorkload {
            name,
            pattern,
            bytes,
            lines,
            base: BASE,
            accesses_left: total,
            total,
            write_ratio,
            cursor: 0,
            rng: Rng::new(seed ^ 0x7061_7474),
            allocated: false,
        }
    }

    pub fn uniform(scale: f64, seed: u64) -> PatternWorkload {
        Self::new("uniform", Pattern::Uniform, scale, seed, 0.3)
    }

    pub fn zipfian(scale: f64, seed: u64) -> PatternWorkload {
        let bytes = ((200.0 * scale) as u64).max(1) * MB;
        let z = Zipf::new(bytes / LINE, 0.99);
        Self::new("zipfian", Pattern::Zipfian(z), scale, seed, 0.3)
    }

    pub fn stream(scale: f64) -> PatternWorkload {
        Self::new("stream", Pattern::Stream, scale, 0, 0.5)
    }

    /// Zipfian traffic over the *shared* range (multihost coherency
    /// studies): every host built this way touches the same addresses.
    pub fn shared(scale: f64, seed: u64, write_ratio: f64) -> PatternWorkload {
        let bytes = ((50.0 * scale) as u64).max(1) * MB;
        let z = Zipf::new(bytes / LINE, 0.9);
        let mut wl = Self::new("shared", Pattern::Zipfian(z), scale, seed, write_ratio);
        wl.bytes = bytes;
        wl.lines = bytes / LINE;
        wl.base = SHARED_BASE;
        wl.accesses_left = wl.lines * 8;
        wl.total = wl.accesses_left;
        wl
    }

    /// Tunable constructor for experiments.
    pub fn custom(
        ws_mb: u64,
        accesses: u64,
        write_ratio: f64,
        zipf_theta: Option<f64>,
        seed: u64,
    ) -> PatternWorkload {
        let bytes = ws_mb.max(1) * MB;
        let lines = bytes / LINE;
        let pattern = match zipf_theta {
            Some(t) => Pattern::Zipfian(Zipf::new(lines, t)),
            None => Pattern::Uniform,
        };
        PatternWorkload {
            name: "custom",
            pattern,
            bytes,
            lines,
            base: BASE,
            accesses_left: accesses,
            total: accesses,
            write_ratio,
            cursor: 0,
            rng: Rng::new(seed),
            allocated: false,
        }
    }
}

impl Workload for PatternWorkload {
    fn name(&self) -> &str {
        self.name
    }

    fn next_event(&mut self) -> Option<WlEvent> {
        if !self.allocated {
            self.allocated = true;
            return Some(WlEvent::Alloc(AllocEvent {
                kind: AllocKind::Mmap,
                addr: self.base,
                len: self.bytes,
                t_ns: 1_000.0,
            }));
        }
        if self.accesses_left == 0 {
            return None;
        }
        self.accesses_left -= 1;
        let line = match &self.pattern {
            Pattern::Uniform => self.rng.below(self.lines),
            Pattern::Zipfian(z) => z.sample(&mut self.rng),
            Pattern::Stream => {
                let l = self.cursor;
                self.cursor = (self.cursor + 1) % self.lines;
                l
            }
        };
        let is_write = self.rng.f64() < self.write_ratio;
        Some(WlEvent::Access(Access { addr: self.base + line * LINE, is_write }))
    }

    /// Native batched emission: the pattern branch is hoisted out of
    /// the per-event loop, so each batch runs one tight monomorphic
    /// loop (same RNG call order as `next_event`, hence an identical
    /// event sequence).
    fn next_batch(&mut self, sink: &mut Vec<WlEvent>, budget: usize) -> bool {
        let mut left = budget as u64;
        if left == 0 {
            return true;
        }
        if !self.allocated {
            self.allocated = true;
            sink.push(WlEvent::Alloc(AllocEvent {
                kind: AllocKind::Mmap,
                addr: self.base,
                len: self.bytes,
                t_ns: 1_000.0,
            }));
            left -= 1;
        }
        let run = self.accesses_left.min(left);
        let base = self.base;
        let lines = self.lines;
        let wr = self.write_ratio;
        match &mut self.pattern {
            Pattern::Uniform => {
                for _ in 0..run {
                    let line = self.rng.below(lines);
                    let is_write = self.rng.f64() < wr;
                    sink.push(WlEvent::Access(Access { addr: base + line * LINE, is_write }));
                }
            }
            Pattern::Zipfian(z) => {
                for _ in 0..run {
                    let line = z.sample(&mut self.rng);
                    let is_write = self.rng.f64() < wr;
                    sink.push(WlEvent::Access(Access { addr: base + line * LINE, is_write }));
                }
            }
            Pattern::Stream => {
                for _ in 0..run {
                    let line = self.cursor;
                    self.cursor = (self.cursor + 1) % lines;
                    let is_write = self.rng.f64() < wr;
                    sink.push(WlEvent::Access(Access { addr: base + line * LINE, is_write }));
                }
            }
        }
        self.accesses_left -= run;
        left -= run;
        // finished mid-batch: report exhaustion like next_event's None
        !(self.accesses_left == 0 && left > 0)
    }

    fn total_accesses_hint(&self) -> u64 {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_spreads_over_working_set() {
        let mut wl = PatternWorkload::uniform(0.01, 3);
        wl.next_event();
        let mut lo = 0u64;
        let mut hi = 0u64;
        let lines = wl.lines;
        for _ in 0..20_000 {
            if let Some(WlEvent::Access(a)) = wl.next_event() {
                let line = (a.addr - BASE) / LINE;
                if line < lines / 2 {
                    lo += 1;
                } else {
                    hi += 1;
                }
            }
        }
        let ratio = lo as f64 / (lo + hi) as f64;
        assert!((0.45..0.55).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn zipfian_is_skewed() {
        let mut wl = PatternWorkload::zipfian(0.01, 3);
        wl.next_event();
        let lines = wl.lines;
        let mut head = 0u64;
        let mut n = 0u64;
        for _ in 0..20_000 {
            if let Some(WlEvent::Access(a)) = wl.next_event() {
                n += 1;
                if (a.addr - BASE) / LINE < lines / 100 {
                    head += 1;
                }
            }
        }
        assert!(head as f64 / n as f64 > 0.3, "head fraction too low");
    }

    #[test]
    fn stream_is_sequential() {
        let mut wl = PatternWorkload::stream(0.01);
        wl.next_event();
        let mut prev = None;
        for _ in 0..1000 {
            if let Some(WlEvent::Access(a)) = wl.next_event() {
                if let Some(p) = prev {
                    assert_eq!(a.addr - p, LINE);
                }
                prev = Some(a.addr);
            }
        }
    }

    #[test]
    fn write_ratio_respected() {
        let mut wl = PatternWorkload::custom(2, 50_000, 0.25, None, 11);
        wl.next_event();
        let mut writes = 0u64;
        let mut n = 0u64;
        while let Some(WlEvent::Access(a)) = wl.next_event() {
            n += 1;
            if a.is_write {
                writes += 1;
            }
        }
        let ratio = writes as f64 / n as f64;
        assert!((0.23..0.27).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn custom_access_budget_exact() {
        let mut wl = PatternWorkload::custom(1, 1234, 0.5, Some(0.9), 1);
        let mut n = 0;
        while wl.next_event().is_some() {
            n += 1;
        }
        assert_eq!(n, 1234 + 1); // + the alloc event
    }
}

//! The five allocation microbenchmarks of the paper's §4 (Table 1).
//!
//! Each allocates a working set through a different interface and then
//! sweeps it sequentially, one access per cacheline, mirroring the
//! paper's description ("allocate memory with different system calls
//! ... and perform sequential writes to the allocated memory"). The
//! interfaces differ in how the allocation event stream looks:
//!
//!   mmap_read / mmap_write — one big anonymous mmap, then reads/writes;
//!   sbrk   — heap grown in 1 MB brk increments, each written as it grows;
//!   malloc — many 64 KB chunks (glibc serves these via brk/mmap mix;
//!            we emit malloc events, which is what eBPF uprobes see);
//!   calloc — one huge zeroed region: calloc's zeroing pass *is* a
//!            sequential write pass, then one more write sweep.
//!
//! Paper working sets: 100 MB (micro), 10 GB (calloc), scaled by `scale`.

use crate::trace::{Access, AllocEvent, AllocKind, WlEvent};

use super::Workload;

const LINE: u64 = 64;
const MB: u64 = 1 << 20;
/// Synthetic virtual address bases, disjoint per region class.
const MMAP_BASE: u64 = 0x7f00_0000_0000;
const HEAP_BASE: u64 = 0x5600_0000_0000;

#[derive(Clone, Copy, Debug, PartialEq)]
enum Mode {
    MmapRead,
    MmapWrite,
    Sbrk,
    Malloc,
    Calloc,
}

#[derive(Clone, Copy, Debug)]
enum Phase {
    /// Emit the allocation event for chunk `i`, then its sweep.
    Alloc { chunk: u64 },
    /// Sweep chunk `i` at line index `line`.
    Sweep { chunk: u64, line: u64 },
    /// Final extra sweep over everything (calloc only), line index.
    FinalSweep { line: u64 },
    Done,
}

pub struct MicroBench {
    name: &'static str,
    mode: Mode,
    /// Total working set, bytes (multiple of chunk size).
    total: u64,
    /// Allocation granularity, bytes.
    chunk: u64,
    /// ns of virtual CPU time per allocation call (syscall cost).
    alloc_cost_ns: f64,
    phase: Phase,
    vtime_ns: f64,
}

impl MicroBench {
    fn new(
        name: &'static str,
        mode: Mode,
        total_bytes: u64,
        chunk: u64,
        alloc_cost_ns: f64,
    ) -> Self {
        let chunk = chunk.min(total_bytes).max(LINE);
        let total = (total_bytes / chunk).max(1) * chunk;
        MicroBench {
            name,
            mode,
            total,
            chunk,
            alloc_cost_ns,
            phase: Phase::Alloc { chunk: 0 },
            vtime_ns: 0.0,
        }
    }

    pub fn mmap_read(scale: f64) -> Self {
        let ws = ((100.0 * scale) as u64).max(1) * MB;
        Self::new("mmap_read", Mode::MmapRead, ws, ws, 2_000.0)
    }

    pub fn mmap_write(scale: f64) -> Self {
        let ws = ((100.0 * scale) as u64).max(1) * MB;
        Self::new("mmap_write", Mode::MmapWrite, ws, ws, 2_000.0)
    }

    pub fn sbrk(scale: f64) -> Self {
        let ws = ((100.0 * scale) as u64).max(1) * MB;
        Self::new("sbrk", Mode::Sbrk, ws, MB, 700.0)
    }

    pub fn malloc(scale: f64) -> Self {
        let ws = ((100.0 * scale) as u64).max(1) * MB;
        Self::new("malloc", Mode::Malloc, ws, 64 << 10, 120.0)
    }

    pub fn calloc(scale: f64) -> Self {
        // paper: 10 GB working set for calloc
        let ws = ((10_240.0 * scale) as u64).max(1) * MB;
        Self::new("calloc", Mode::Calloc, ws, ws, 3_000.0)
    }

    fn base(&self) -> u64 {
        match self.mode {
            Mode::MmapRead | Mode::MmapWrite | Mode::Calloc => MMAP_BASE,
            Mode::Sbrk | Mode::Malloc => HEAP_BASE,
        }
    }

    fn chunks(&self) -> u64 {
        self.total / self.chunk
    }

    fn lines_per_chunk(&self) -> u64 {
        self.chunk / LINE
    }

    fn alloc_kind(&self) -> AllocKind {
        match self.mode {
            Mode::MmapRead | Mode::MmapWrite => AllocKind::Mmap,
            Mode::Sbrk => AllocKind::Sbrk,
            Mode::Malloc => AllocKind::Malloc,
            Mode::Calloc => AllocKind::Calloc,
        }
    }

    fn sweep_is_write(&self) -> bool {
        !matches!(self.mode, Mode::MmapRead)
    }
}

impl Workload for MicroBench {
    fn name(&self) -> &str {
        self.name
    }

    fn next_event(&mut self) -> Option<WlEvent> {
        loop {
            match self.phase {
                Phase::Alloc { chunk } => {
                    if chunk >= self.chunks() {
                        // all chunks allocated+swept; calloc gets one
                        // extra full write pass (the post-zeroing use).
                        self.phase = if self.mode == Mode::Calloc {
                            Phase::FinalSweep { line: 0 }
                        } else {
                            Phase::Done
                        };
                        continue;
                    }
                    self.phase = Phase::Sweep { chunk, line: 0 };
                    self.vtime_ns += self.alloc_cost_ns;
                    return Some(WlEvent::Alloc(AllocEvent {
                        kind: self.alloc_kind(),
                        addr: self.base() + chunk * self.chunk,
                        len: self.chunk,
                        t_ns: self.vtime_ns,
                    }));
                }
                Phase::Sweep { chunk, line } => {
                    if line >= self.lines_per_chunk() {
                        self.phase = Phase::Alloc { chunk: chunk + 1 };
                        continue;
                    }
                    self.phase = Phase::Sweep { chunk, line: line + 1 };
                    return Some(WlEvent::Access(Access {
                        addr: self.base() + chunk * self.chunk + line * LINE,
                        is_write: self.sweep_is_write(),
                    }));
                }
                Phase::FinalSweep { line } => {
                    if line >= self.total / LINE {
                        self.phase = Phase::Done;
                        continue;
                    }
                    self.phase = Phase::FinalSweep { line: line + 1 };
                    return Some(WlEvent::Access(Access {
                        addr: self.base() + line * LINE,
                        is_write: true,
                    }));
                }
                Phase::Done => return None,
            }
        }
    }

    /// Native batched emission: the sequential sweeps are emitted as
    /// run-length inner loops (one bounds check per run, not per line),
    /// so the coordinator's event pump stays monomorphic. Emits the
    /// exact sequence `next_event` would.
    fn next_batch(&mut self, sink: &mut Vec<WlEvent>, budget: usize) -> bool {
        let mut left = budget as u64;
        while left > 0 {
            match self.phase {
                Phase::Alloc { chunk } => {
                    if chunk >= self.chunks() {
                        self.phase = if self.mode == Mode::Calloc {
                            Phase::FinalSweep { line: 0 }
                        } else {
                            Phase::Done
                        };
                        continue;
                    }
                    self.phase = Phase::Sweep { chunk, line: 0 };
                    self.vtime_ns += self.alloc_cost_ns;
                    sink.push(WlEvent::Alloc(AllocEvent {
                        kind: self.alloc_kind(),
                        addr: self.base() + chunk * self.chunk,
                        len: self.chunk,
                        t_ns: self.vtime_ns,
                    }));
                    left -= 1;
                }
                Phase::Sweep { chunk, line } => {
                    let lines = self.lines_per_chunk();
                    if line >= lines {
                        self.phase = Phase::Alloc { chunk: chunk + 1 };
                        continue;
                    }
                    let run = (lines - line).min(left);
                    let base = self.base() + chunk * self.chunk + line * LINE;
                    let is_write = self.sweep_is_write();
                    for i in 0..run {
                        sink.push(WlEvent::Access(Access { addr: base + i * LINE, is_write }));
                    }
                    self.phase = Phase::Sweep { chunk, line: line + run };
                    left -= run;
                }
                Phase::FinalSweep { line } => {
                    let lines = self.total / LINE;
                    if line >= lines {
                        self.phase = Phase::Done;
                        continue;
                    }
                    let run = (lines - line).min(left);
                    let base = self.base() + line * LINE;
                    for i in 0..run {
                        sink.push(WlEvent::Access(Access {
                            addr: base + i * LINE,
                            is_write: true,
                        }));
                    }
                    self.phase = Phase::FinalSweep { line: line + run };
                    left -= run;
                }
                Phase::Done => return false,
            }
        }
        true
    }

    fn total_accesses_hint(&self) -> u64 {
        let sweeps = if self.mode == Mode::Calloc { 2 } else { 1 };
        self.total / LINE * sweeps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(mut wl: MicroBench) -> (Vec<AllocEvent>, Vec<Access>) {
        let mut allocs = Vec::new();
        let mut accesses = Vec::new();
        while let Some(ev) = wl.next_event() {
            match ev {
                WlEvent::Alloc(a) => allocs.push(a),
                WlEvent::Access(a) => accesses.push(a),
            }
        }
        (allocs, accesses)
    }

    #[test]
    fn mmap_read_allocates_once_then_reads() {
        let (allocs, accesses) = drain(MicroBench::mmap_read(0.01)); // 1 MB
        assert_eq!(allocs.len(), 1);
        assert_eq!(allocs[0].kind, AllocKind::Mmap);
        assert_eq!(allocs[0].len, MB);
        assert_eq!(accesses.len(), (MB / LINE) as usize);
        assert!(accesses.iter().all(|a| !a.is_write));
    }

    #[test]
    fn mmap_write_writes() {
        let (_, accesses) = drain(MicroBench::mmap_write(0.01));
        assert!(accesses.iter().all(|a| a.is_write));
    }

    #[test]
    fn sbrk_grows_in_increments() {
        let (allocs, accesses) = drain(MicroBench::sbrk(0.05)); // 5 MB
        assert_eq!(allocs.len(), 5);
        assert!(allocs.iter().all(|a| a.kind == AllocKind::Sbrk && a.len == MB));
        // heap grows contiguously
        for (i, a) in allocs.iter().enumerate() {
            assert_eq!(a.addr, HEAP_BASE + i as u64 * MB);
        }
        assert_eq!(accesses.len(), (5 * MB / LINE) as usize);
    }

    #[test]
    fn malloc_many_small_chunks() {
        let (allocs, _) = drain(MicroBench::malloc(0.01)); // 1 MB, 64 KB chunks
        assert_eq!(allocs.len(), 16);
        assert!(allocs.iter().all(|a| a.kind == AllocKind::Malloc));
    }

    #[test]
    fn calloc_double_sweeps() {
        let wl = MicroBench::calloc(0.0005); // ~5 MB
        let hint = wl.total_accesses_hint();
        let (allocs, accesses) = drain(wl);
        assert_eq!(allocs.len(), 1);
        assert_eq!(allocs[0].kind, AllocKind::Calloc);
        assert_eq!(accesses.len() as u64, hint);
        // two full passes over every line
        assert_eq!(hint, allocs[0].len / LINE * 2);
    }

    #[test]
    fn sweep_is_sequential_by_line() {
        let (_, accesses) = drain(MicroBench::mmap_write(0.01));
        for w in accesses.windows(2) {
            assert_eq!(w[1].addr - w[0].addr, LINE);
        }
    }

    #[test]
    fn alloc_events_carry_monotone_time() {
        let (allocs, _) = drain(MicroBench::sbrk(0.03));
        for w in allocs.windows(2) {
            assert!(w[1].t_ns > w[0].t_ns);
        }
    }

    #[test]
    fn scale_changes_working_set() {
        let a = MicroBench::mmap_read(1.0);
        let b = MicroBench::mmap_read(0.01);
        assert_eq!(a.total, 100 * MB);
        assert_eq!(b.total, MB);
    }

    #[test]
    fn batched_emission_identical() {
        use crate::workload::assert_same_stream;
        for (mk, batch) in [
            (MicroBench::mmap_read as fn(f64) -> MicroBench, 1usize),
            (MicroBench::mmap_write, 3),
            (MicroBench::sbrk, 100),
            (MicroBench::malloc, 1000),
            (MicroBench::calloc, 4096),
        ] {
            let mut a = mk(0.003);
            let mut b = mk(0.003);
            assert_same_stream(&mut a, &mut b, batch);
        }
    }
}

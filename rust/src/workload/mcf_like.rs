//! `mcf_like`: SPEC2017 505.mcf's dominant memory behaviour.
//!
//! mcf runs network simplex over a large arc/node graph; its signature
//! is pointer chasing with near-zero spatial locality plus periodic
//! sequential passes over the arc array (the pricing step). This twin
//! reproduces both phases:
//!
//!   * node array: a random permutation cycle chased for `hops` steps
//!     (every hop is a dependent read of a random cacheline);
//!   * arc array: every `PRICE_EVERY` hops, a sequential scan segment
//!     with a read + occasional write (cost update).
//!
//! Working set defaults to ~340 MB like the real benchmark's resident
//! set, scaled by `scale`.

use crate::trace::{Access, AllocEvent, AllocKind, WlEvent};
use crate::util::rng::Rng;

use super::Workload;

const LINE: u64 = 64;
const MB: u64 = 1 << 20;
const NODE_BASE: u64 = 0x7f10_0000_0000;
const ARC_BASE: u64 = 0x7f20_0000_0000;
/// One pricing scan burst per this many chase hops.
const PRICE_EVERY: u64 = 64;
/// Length of each pricing scan burst, lines.
const PRICE_BURST: u64 = 32;

enum Phase {
    AllocNodes,
    AllocArcs,
    Run,
    Done,
}

pub struct McfLike {
    nodes_bytes: u64,
    arcs_bytes: u64,
    hops_left: u64,
    total_hops: u64,
    phase: Phase,
    /// Current node index (line index into node array).
    cursor: u64,
    /// Multiplicative step of the permutation cycle (odd => full cycle
    /// over power-of-two domain).
    step: u64,
    node_lines: u64,
    arc_lines: u64,
    /// Pricing-burst state: remaining lines in the current burst.
    burst_left: u64,
    arc_cursor: u64,
    hop_in_round: u64,
    rng: Rng,
    vtime_ns: f64,
}

impl McfLike {
    pub fn new(scale: f64, seed: u64) -> McfLike {
        let nodes_bytes = (((240.0 * scale) as u64).max(1) * MB).next_power_of_two();
        let arcs_bytes = ((100.0 * scale) as u64).max(1) * MB;
        let node_lines = nodes_bytes / LINE;
        let mut rng = Rng::new(seed ^ 0x6d63_665f); // "mcf_"
        // odd multiplier ~ golden ratio of the domain: visits all lines
        let step = (0x9E37_79B9_7F4A_7C15u64 | 1) % node_lines.max(2) | 1;
        let total_hops = (node_lines * 4).max(1024);
        McfLike {
            nodes_bytes,
            arcs_bytes,
            hops_left: total_hops,
            total_hops,
            phase: Phase::AllocNodes,
            cursor: rng.below(node_lines.max(1)),
            step,
            node_lines,
            arc_lines: arcs_bytes / LINE,
            burst_left: 0,
            arc_cursor: 0,
            hop_in_round: 0,
            rng,
            vtime_ns: 0.0,
        }
    }
}

impl Workload for McfLike {
    fn name(&self) -> &str {
        "mcf_like"
    }

    fn next_event(&mut self) -> Option<WlEvent> {
        loop {
            match self.phase {
                Phase::AllocNodes => {
                    self.phase = Phase::AllocArcs;
                    self.vtime_ns += 2_000.0;
                    return Some(WlEvent::Alloc(AllocEvent {
                        kind: AllocKind::Mmap,
                        addr: NODE_BASE,
                        len: self.nodes_bytes,
                        t_ns: self.vtime_ns,
                    }));
                }
                Phase::AllocArcs => {
                    self.phase = Phase::Run;
                    self.vtime_ns += 2_000.0;
                    return Some(WlEvent::Alloc(AllocEvent {
                        kind: AllocKind::Malloc,
                        addr: ARC_BASE,
                        len: self.arcs_bytes,
                        t_ns: self.vtime_ns,
                    }));
                }
                Phase::Run => {
                    if self.burst_left > 0 {
                        // pricing scan: sequential arc reads, 1/8 writes
                        self.burst_left -= 1;
                        let line = self.arc_cursor % self.arc_lines.max(1);
                        self.arc_cursor += 1;
                        let is_write = self.burst_left % 8 == 0;
                        return Some(WlEvent::Access(Access {
                            addr: ARC_BASE + line * LINE,
                            is_write,
                        }));
                    }
                    if self.hops_left == 0 {
                        self.phase = Phase::Done;
                        continue;
                    }
                    self.hops_left -= 1;
                    self.hop_in_round += 1;
                    if self.hop_in_round >= PRICE_EVERY {
                        self.hop_in_round = 0;
                        self.burst_left = PRICE_BURST.min(self.arc_lines);
                    }
                    // dependent chase: permutation walk + jitter so the
                    // prefetcher-unfriendly behaviour survives
                    self.cursor = (self
                        .cursor
                        .wrapping_mul(self.step)
                        .wrapping_add(self.rng.below(7)))
                        % self.node_lines.max(1);
                    return Some(WlEvent::Access(Access {
                        addr: NODE_BASE + self.cursor * LINE,
                        is_write: false,
                    }));
                }
                Phase::Done => return None,
            }
        }
    }

    /// Native batched emission: pricing bursts are emitted as one inner
    /// run per burst, and chase hops loop without per-event dispatch.
    /// Emits the exact sequence `next_event` would (same RNG order).
    fn next_batch(&mut self, sink: &mut Vec<WlEvent>, budget: usize) -> bool {
        let mut left = budget as u64;
        while left > 0 {
            match self.phase {
                Phase::AllocNodes => {
                    self.phase = Phase::AllocArcs;
                    self.vtime_ns += 2_000.0;
                    sink.push(WlEvent::Alloc(AllocEvent {
                        kind: AllocKind::Mmap,
                        addr: NODE_BASE,
                        len: self.nodes_bytes,
                        t_ns: self.vtime_ns,
                    }));
                    left -= 1;
                }
                Phase::AllocArcs => {
                    self.phase = Phase::Run;
                    self.vtime_ns += 2_000.0;
                    sink.push(WlEvent::Alloc(AllocEvent {
                        kind: AllocKind::Malloc,
                        addr: ARC_BASE,
                        len: self.arcs_bytes,
                        t_ns: self.vtime_ns,
                    }));
                    left -= 1;
                }
                Phase::Run => {
                    if self.burst_left > 0 {
                        // pricing scan: one run per burst segment
                        let run = self.burst_left.min(left);
                        let arc_lines = self.arc_lines.max(1);
                        for _ in 0..run {
                            self.burst_left -= 1;
                            let line = self.arc_cursor % arc_lines;
                            self.arc_cursor += 1;
                            let is_write = self.burst_left % 8 == 0;
                            sink.push(WlEvent::Access(Access {
                                addr: ARC_BASE + line * LINE,
                                is_write,
                            }));
                        }
                        left -= run;
                        continue;
                    }
                    if self.hops_left == 0 {
                        self.phase = Phase::Done;
                        continue;
                    }
                    // dependent chase hops until the budget runs out or
                    // a pricing burst becomes due
                    let node_lines = self.node_lines.max(1);
                    while left > 0 && self.hops_left > 0 {
                        self.hops_left -= 1;
                        self.hop_in_round += 1;
                        let burst_due = self.hop_in_round >= PRICE_EVERY;
                        if burst_due {
                            self.hop_in_round = 0;
                            self.burst_left = PRICE_BURST.min(self.arc_lines);
                        }
                        self.cursor = (self
                            .cursor
                            .wrapping_mul(self.step)
                            .wrapping_add(self.rng.below(7)))
                            % node_lines;
                        sink.push(WlEvent::Access(Access {
                            addr: NODE_BASE + self.cursor * LINE,
                            is_write: false,
                        }));
                        left -= 1;
                        if burst_due {
                            break;
                        }
                    }
                }
                Phase::Done => return false,
            }
        }
        true
    }

    fn total_accesses_hint(&self) -> u64 {
        self.total_hops + self.total_hops / PRICE_EVERY * PRICE_BURST
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocates_two_regions_then_chases() {
        let mut wl = McfLike::new(0.001, 1);
        let a = wl.next_event().unwrap();
        let b = wl.next_event().unwrap();
        assert!(matches!(a, WlEvent::Alloc(e) if e.addr == NODE_BASE));
        assert!(matches!(b, WlEvent::Alloc(e) if e.addr == ARC_BASE));
        let c = wl.next_event().unwrap();
        assert!(matches!(c, WlEvent::Access(_)));
    }

    #[test]
    fn chase_has_poor_locality() {
        let mut wl = McfLike::new(0.01, 2);
        wl.next_event();
        wl.next_event();
        let mut node_addrs = Vec::new();
        while let Some(ev) = wl.next_event() {
            if let WlEvent::Access(a) = ev {
                if a.addr >= NODE_BASE && a.addr < ARC_BASE {
                    node_addrs.push(a.addr);
                }
            }
            if node_addrs.len() >= 1000 {
                break;
            }
        }
        // fraction of consecutive accesses within 4KB must be small
        let near = node_addrs
            .windows(2)
            .filter(|w| w[0].abs_diff(w[1]) < 4096)
            .count();
        assert!(near < node_addrs.len() / 10, "near={near}");
    }

    #[test]
    fn emits_pricing_bursts_with_writes() {
        let mut wl = McfLike::new(0.01, 3);
        let mut arc_writes = 0;
        let mut arc_reads = 0;
        for _ in 0..200_000 {
            match wl.next_event() {
                Some(WlEvent::Access(a)) if a.addr >= ARC_BASE => {
                    if a.is_write {
                        arc_writes += 1;
                    } else {
                        arc_reads += 1;
                    }
                }
                None => break,
                _ => {}
            }
        }
        assert!(arc_reads > 0, "no pricing reads");
        assert!(arc_writes > 0, "no pricing writes");
        assert!(arc_reads > arc_writes);
    }

    #[test]
    fn terminates() {
        let mut wl = McfLike::new(0.001, 4);
        let hint = wl.total_accesses_hint();
        let mut n = 0u64;
        while wl.next_event().is_some() {
            n += 1;
            assert!(n < hint * 3 + 100);
        }
        assert!(n > hint / 2);
    }

    #[test]
    fn batched_emission_identical() {
        use crate::workload::assert_same_stream;
        for batch in [1usize, 17, 4096] {
            let mut a = McfLike::new(0.002, 9);
            let mut b = McfLike::new(0.002, 9);
            assert_same_stream(&mut a, &mut b, batch);
        }
    }

    #[test]
    fn chase_covers_many_lines() {
        let mut wl = McfLike::new(0.005, 5);
        wl.next_event();
        wl.next_event();
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..50_000 {
            match wl.next_event() {
                Some(WlEvent::Access(a)) if a.addr < ARC_BASE => {
                    seen.insert(a.addr);
                }
                None => break,
                _ => {}
            }
        }
        assert!(seen.len() > 1000, "chase revisits too few lines: {}", seen.len());
    }
}

//! Metrics: streaming histograms and summary statistics for per-epoch
//! and per-request quantities (delay distributions, epoch durations,
//! analyzer call latencies).

/// Log-scaled histogram over [lo, hi) with `buckets` bins, plus exact
/// running moments. Constant memory, O(1) record.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    n: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Histogram {
        assert!(lo > 0.0 && hi > lo && buckets > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; buckets + 2], // +underflow/overflow
            n: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket_of(&self, x: f64) -> usize {
        if x < self.lo {
            return 0;
        }
        if x >= self.hi {
            return self.counts.len() - 1;
        }
        let inner = self.counts.len() - 2;
        let f = (x / self.lo).ln() / (self.hi / self.lo).ln();
        1 + ((f * inner as f64) as usize).min(inner - 1)
    }

    pub fn record(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        let b = self.bucket_of(x.max(f64::MIN_POSITIVE));
        self.counts[b] += 1;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let m = self.mean();
        (self.sum_sq / self.n as f64 - m * m).max(0.0).sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Approximate quantile from bucket boundaries (q in [0, 1]).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.n as f64) as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > target {
                let inner = self.counts.len() - 2;
                if i == 0 {
                    return self.min();
                }
                if i == self.counts.len() - 1 {
                    return self.max();
                }
                // geometric midpoint of the bucket
                let frac = (i - 1) as f64 / inner as f64;
                let frac2 = i as f64 / inner as f64;
                let a = self.lo * (self.hi / self.lo).powf(frac);
                let b = self.lo * (self.hi / self.lo).powf(frac2);
                return (a * b).sqrt();
            }
        }
        self.max()
    }

    pub fn summary(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.3} sd={:.3} min={:.3} p50={:.3} p95={:.3} p99={:.3} max={:.3}",
            self.n,
            self.mean(),
            self.stddev(),
            self.min(),
            self.quantile(0.5),
            self.quantile(0.95),
            self.quantile(0.99),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_exact() {
        let mut h = Histogram::new(1.0, 1000.0, 32);
        for x in [10.0, 20.0, 30.0] {
            h.record(x);
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean() - 20.0).abs() < 1e-9);
        assert_eq!(h.min(), 10.0);
        assert_eq!(h.max(), 30.0);
    }

    #[test]
    fn quantiles_are_ordered_and_bracketed() {
        let mut h = Histogram::new(1.0, 1e6, 64);
        for i in 1..=1000 {
            h.record(i as f64);
        }
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!((400.0..650.0).contains(&p50), "p50={p50}");
        assert!((800.0..1100.0).contains(&p95), "p95={p95}");
    }

    #[test]
    fn under_overflow_buckets() {
        let mut h = Histogram::new(10.0, 100.0, 4);
        h.record(1.0); // underflow
        h.record(1e9); // overflow
        assert_eq!(h.count(), 2);
        assert_eq!(h.quantile(0.0), 1.0);
        assert_eq!(h.quantile(1.0), 1e9);
    }

    #[test]
    fn stddev_of_constant_is_zero() {
        let mut h = Histogram::new(1.0, 100.0, 8);
        for _ in 0..50 {
            h.record(42.0);
        }
        assert!(h.stddev() < 1e-9);
    }

    #[test]
    fn summary_formats() {
        let mut h = Histogram::new(1.0, 100.0, 8);
        h.record(5.0);
        let s = h.summary("lat");
        assert!(s.contains("lat:"));
        assert!(s.contains("n=1"));
    }
}

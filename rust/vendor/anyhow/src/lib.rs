//! Offline-vendored minimal subset of the `anyhow` API.
//!
//! The container this repo builds in has no crates.io access, so the
//! workspace vendors the small slice of `anyhow` the codebase actually
//! uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros, and `?`-conversion from any `std::error::Error`.
//! Swapping back to the real crate is a one-line Cargo.toml change —
//! no call sites need to move.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error: a message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Build an error wrapping an underlying `std::error::Error`.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// Iterate the `source()` chain of the wrapped error, if any.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        let mut next: Option<&(dyn StdError + 'static)> =
            self.source.as_ref().map(|b| b.as_ref() as &(dyn StdError + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        for cause in self.chain().skip(1) {
            write!(f, "\n  caused by: {cause}")?;
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// std::error::Error — that is what makes this blanket `From` legal.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` to results (subset of anyhow's).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let _ = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = fails_io().unwrap_err();
        assert!(!err.to_string().is_empty());
        assert!(err.chain().count() >= 1);
    }

    #[test]
    fn macros_format() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 3");
        let e = anyhow!("got {} of {}", 1, 2);
        assert_eq!(e.to_string(), "got 1 of 2");
        let io = std::io::Error::new(std::io::ErrorKind::Other, "boom");
        let e = anyhow!(io);
        assert_eq!(e.to_string(), "boom");
    }

    #[test]
    fn ensure_returns_err() {
        fn check(n: u32) -> Result<u32> {
            ensure!(n < 10, "n too big: {n}");
            Ok(n)
        }
        assert!(check(3).is_ok());
        assert_eq!(check(30).unwrap_err().to_string(), "n too big: 30");
    }

    #[test]
    fn bail_returns_err() {
        fn go() -> Result<()> {
            bail!("nope");
        }
        assert_eq!(go().unwrap_err().to_string(), "nope");
    }

    #[test]
    fn context_wraps_message() {
        let e = fails_io().context("loading config").unwrap_err();
        assert!(e.to_string().starts_with("loading config: "));
    }
}

//! Bench A2 — congestion vs hosts sharing a switch (paper §2: "each
//! CXL switch can cause congestion, when multiple hosts use the switch
//! at the same time"). Regenerates the hosts → congestion-delay series.
//!
//!     cargo bench --offline --bench fig_congestion

use cxlmemsim::coordinator::SimConfig;
use cxlmemsim::multihost;
use cxlmemsim::prelude::*;
use cxlmemsim::util::benchutil::markdown_table;
use cxlmemsim::workload;

fn main() {
    let scale: f64 = std::env::var("CXLMEMSIM_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.005);
    let mut cfg = SimConfig::default();
    cfg.scale = scale;
    cfg.cache_scale = 32;
    cfg.backend = AnalyzerBackend::Native;
    let topo = builtin::wide(); // four pools behind one switch

    println!("## A2: congestion vs hosts sharing a switch (topology wide, scale {scale})\n");
    let mut rows = Vec::new();
    let mut series = Vec::new();
    for hosts in [1usize, 2, 4, 6, 8] {
        let workloads: Vec<_> = (0..hosts)
            .map(|i| workload::by_name("stream", scale, cfg.seed + i as u64).unwrap())
            .collect();
        let rep = multihost::run_shared(&topo, &cfg, workloads).unwrap();
        let cong_per_epoch = rep.cong_delay_ns / rep.epochs.max(1) as f64;
        let bw_per_epoch = rep.bwd_delay_ns / rep.epochs.max(1) as f64;
        series.push((hosts, cong_per_epoch));
        rows.push(vec![
            hosts.to_string(),
            rep.epochs.to_string(),
            format!("{:.3}", cong_per_epoch / 1e3),
            format!("{:.3}", bw_per_epoch / 1e3),
            format!("{:.3}x", rep.mean_slowdown()),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["Hosts", "Epochs", "Cong/epoch (µs)", "BW/epoch (µs)", "Mean slowdown"],
            &rows
        )
    );
    // shape: congestion/epoch strictly grows with host count and grows
    // super-linearly from 1 to 8 hosts
    for w in series.windows(2) {
        assert!(
            w[1].1 >= w[0].1,
            "congestion must not shrink with more hosts: {:?}",
            series
        );
    }
    let (h0, c0) = series[0];
    let (h1, c1) = *series.last().unwrap();
    if c0 > 0.0 {
        let growth = c1 / c0;
        let linear = h1 as f64 / h0 as f64;
        println!("\ncongestion growth 1->{h1} hosts: {growth:.1}x (linear would be {linear:.1}x)");
        assert!(growth > linear, "switch sharing must be super-linear");
    }

    // second series: hosts *sharing memory* — coherence invalidations
    // (paper §1: "performance impact of CXL.mem pool coherency")
    println!("\n### coherency: hosts sharing one zipfian region\n");
    let mut rows = Vec::new();
    let mut inv_series = Vec::new();
    for hosts in [1usize, 2, 4, 8] {
        let workloads: Vec<_> = (0..hosts)
            .map(|i| workload::by_name("shared", scale, cfg.seed + i as u64).unwrap())
            .collect();
        let rep = multihost::run_shared(&topo, &cfg, workloads).unwrap();
        let inv_per_epoch = rep.invalidations as f64 / rep.epochs.max(1) as f64;
        inv_series.push((hosts, inv_per_epoch));
        rows.push(vec![
            hosts.to_string(),
            rep.invalidations.to_string(),
            format!("{inv_per_epoch:.1}"),
            format!("{:.3}x", rep.mean_slowdown()),
        ]);
    }
    println!(
        "{}",
        markdown_table(&["Sharers", "Invalidations", "Inval/epoch", "Mean slowdown"], &rows)
    );
    assert_eq!(inv_series[0].1, 0.0, "a lone host has no peers to invalidate");
    assert!(
        inv_series.last().unwrap().1 > inv_series[1].1,
        "invalidation pressure must grow with sharers"
    );
}

//! Bench T1 — regenerates the paper's Table 1 (§4, "Performance
//! evaluation of CXLMemSim"): wall-clock of each benchmark run native,
//! under the detailed (gem5-like) baseline, and under CXLMemSim, plus
//! the slowdown factors the paper reports.
//!
//!     cargo bench --offline --bench table1_overhead
//!
//! Env: CXLMEMSIM_BENCH_SCALE (default 0.02), CXLMEMSIM_BENCH_BACKEND
//! (pjrt|native, default pjrt). We do not expect the paper's absolute
//! numbers (different substrate); the *shape* must hold:
//! native < CXLMemSim << detailed, with CXLMemSim orders of magnitude
//! closer to native.

use cxlmemsim::coordinator::{Coordinator, SimConfig};
use cxlmemsim::gem5like::DetailedSim;
use cxlmemsim::prelude::*;
use cxlmemsim::util::benchutil::{markdown_table, time_once};
use cxlmemsim::workload;

fn main() {
    let scale: f64 = std::env::var("CXLMEMSIM_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.02);
    let backend = std::env::var("CXLMEMSIM_BENCH_BACKEND")
        .ok()
        .and_then(|v| AnalyzerBackend::parse(&v))
        .unwrap_or(AnalyzerBackend::Pjrt);

    let mut cfg = SimConfig::default();
    cfg.scale = scale;
    cfg.backend = backend;
    let topo = builtin::fig2();

    println!("## T1: Table 1 overhead (scale {scale}, backend {backend:?}, topology fig2)\n");
    let mut rows = Vec::new();
    let mut geo_sim = 0.0;
    let mut geo_det = 0.0;
    for wl_name in TABLE1_WORKLOADS {
        let mut wl = workload::by_name(wl_name, scale, cfg.seed).unwrap();
        let (_, native) = time_once(|| while wl.next_event().is_some() {});

        let mut det = DetailedSim::new(topo.clone(), cfg.cache_scale, cfg.policy.clone());
        let mut wl = workload::by_name(wl_name, scale, cfg.seed).unwrap();
        let det_rep = det.run(wl.as_mut());

        let mut sim = Coordinator::new(topo.clone(), cfg.clone()).unwrap();
        let rep = sim.run_workload(wl_name).unwrap();

        geo_sim += (rep.wall_s / native).ln();
        geo_det += (det_rep.wall_s / native).ln();
        rows.push(vec![
            wl_name.to_string(),
            format!("{native:.4}"),
            format!("{:.3}", det_rep.wall_s),
            format!("{:.3}", rep.wall_s),
            format!("{:.1}x", det_rep.wall_s / native),
            format!("{:.1}x", rep.wall_s / native),
            format!("{:.3}x", rep.sim_slowdown()),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &[
                "Benchmark",
                "Native (s)",
                "Detailed (s)",
                "CXLMemSim (s)",
                "Det/Nat",
                "Sim/Nat",
                "SimSlowdown"
            ],
            &rows
        )
    );
    let n = TABLE1_WORKLOADS.len() as f64;
    let sim_over = (geo_sim / n).exp();
    let det_over = (geo_det / n).exp();
    println!("\ngeomean: CXLMemSim {sim_over:.1}x native, detailed {det_over:.1}x native");
    println!(
        "CXLMemSim is {:.1}x faster than the detailed baseline \
         (paper: 41.06x native avg, ~73x faster than gem5)",
        det_over / sim_over
    );
    assert!(sim_over < det_over, "shape violated: CXLMemSim must beat detailed");
}

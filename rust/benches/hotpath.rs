//! Bench P1 — hot-path microbenchmarks for the §Perf pass:
//!
//!   * timing-analyzer invocations/s: native mirror vs PJRT single vs
//!     PJRT batched (the L2/L3 boundary cost);
//!   * cache-hierarchy accesses/s (the per-access substrate cost);
//!   * end-to-end coordinator epochs/s and accesses/s.
//!
//!     cargo bench --offline --bench hotpath

use cxlmemsim::cache::CacheHierarchy;
use cxlmemsim::coordinator::{Coordinator, SimConfig};
use cxlmemsim::prelude::*;
use cxlmemsim::runtime::native::NativeAnalyzer;
use cxlmemsim::runtime::pjrt::{PjrtAnalyzer, PjrtBatchAnalyzer};
use cxlmemsim::runtime::shapes;
use cxlmemsim::runtime::{TimingInputs, TimingModel};
use cxlmemsim::util::benchutil::{bench, fmt_secs};
use cxlmemsim::util::rng::Rng;

fn main() {
    let topo = builtin::fig2();
    let tensors = TopoTensors::build(&topo, shapes::NUM_POOLS, shapes::NUM_SWITCHES).unwrap();
    let nbins = shapes::NUM_BINS;
    let dir = shapes::artifacts_dir();
    let n = shapes::NUM_POOLS * nbins;

    let mut rng = Rng::new(4);
    let reads: Vec<f32> = (0..n).map(|_| rng.below(20) as f32).collect();
    let writes: Vec<f32> = (0..n).map(|_| rng.below(10) as f32).collect();
    let inp = || TimingInputs {
        reads: &reads,
        writes: &writes,
        bin_width: 3906.25,
        bytes_per_ev: 64.0,
    };

    println!("## P1: hot-path microbenchmarks\n");

    // --- analyzer invocation cost --------------------------------
    let mut native = NativeAnalyzer::new(&tensors, nbins);
    let s = bench("native analyze", 50, 500, || {
        native.analyze(&inp()).unwrap();
    });
    println!(
        "native analyzer:      {:>10}/call  ({:.0} calls/s)",
        fmt_secs(s.mean_s),
        1.0 / s.mean_s
    );

    let mut pjrt = PjrtAnalyzer::new(&tensors, nbins, &dir).unwrap();
    let s = bench("pjrt analyze", 20, 200, || {
        pjrt.analyze(&inp()).unwrap();
    });
    println!(
        "pjrt analyzer:        {:>10}/call  ({:.0} calls/s)",
        fmt_secs(s.mean_s),
        1.0 / s.mean_s
    );

    let mut batch = PjrtBatchAnalyzer::new(&tensors, nbins, &dir).unwrap();
    let e = batch.batch;
    let breads: Vec<f32> = (0..e * n).map(|_| rng.below(20) as f32).collect();
    let bwrites: Vec<f32> = (0..e * n).map(|_| rng.below(10) as f32).collect();
    let s = bench("pjrt batch analyze", 10, 100, || {
        batch.analyze_batch(&breads, &bwrites, 3906.25, 64.0).unwrap();
    });
    println!(
        "pjrt batch ({e:>2}/call): {:>10}/call  ({:.0} epochs/s effective)",
        fmt_secs(s.mean_s),
        e as f64 / s.mean_s
    );

    // --- cache substrate cost ------------------------------------
    // worst case: uniform-random over 1 GB, every access an LLC miss
    let mut cache = CacheHierarchy::scaled(1);
    let addrs: Vec<u64> = (0..1_000_000u64).map(|_| rng.below(1 << 30) & !63).collect();
    let s = bench("cache 1M misses", 1, 10, || {
        for &a in &addrs {
            cache.access(a, a & 64 != 0);
        }
    });
    println!(
        "cache (all-miss):     {:>10}/1M acc ({:.1} M accesses/s)",
        fmt_secs(s.mean_s),
        1.0 / s.mean_s
    );
    // common case: hot working set, L1-resident
    let mut cache = CacheHierarchy::scaled(1);
    let hot: Vec<u64> = (0..1_000_000u64).map(|_| rng.below(512) * 64).collect();
    let s = bench("cache 1M hits", 1, 10, || {
        for &a in &hot {
            cache.access(a, a & 64 != 0);
        }
    });
    println!(
        "cache (L1-hot):       {:>10}/1M acc ({:.1} M accesses/s)",
        fmt_secs(s.mean_s),
        1.0 / s.mean_s
    );

    // --- end-to-end coordinator ----------------------------------
    for (label, backend) in [("native", AnalyzerBackend::Native), ("pjrt", AnalyzerBackend::Pjrt)] {
        let mut cfg = SimConfig::default();
        cfg.scale = 0.01;
        cfg.cache_scale = 1;
        cfg.backend = backend;
        let mut sim = Coordinator::new(topo.clone(), cfg).unwrap();
        let rep = sim.run_workload("mcf_like").unwrap();
        println!(
            "coordinator[{label:6}]: {:>10} wall, {} epochs ({:.0} epochs/s), {:.1} M accesses/s",
            fmt_secs(rep.wall_s),
            rep.epochs_run,
            rep.epochs_run as f64 / rep.wall_s,
            rep.total_accesses as f64 / rep.wall_s / 1e6
        );
    }
}

//! Bench P1 — hot-path microbenchmarks for the §Perf pass:
//!
//!   * event-pump throughput: per-event (`next_event`, one virtual call
//!     per event) vs batched (`next_batch`, monomorphic inner loop);
//!   * `AllocTracker::pool_of` lookups/s: MRU + flat-index fast path vs
//!     the `BTreeMap::range` walk baseline;
//!   * timing-analyzer invocations/s: native mirror (and, with
//!     `--features pjrt`, PJRT single vs PJRT batched — the L2/L3
//!     boundary cost);
//!   * cache-hierarchy accesses/s (the per-access substrate cost);
//!   * `EpochBins` recording: scalar per-sample `record` vs the staged
//!     `stage` + `record_bulk` scatter the epoch driver uses;
//!   * batched timing analysis: the fused `NativeBatchAnalyzer` kernel
//!     vs E scalar `analyze` calls, plus the sharded E-epoch loop at
//!     1/2/4 worker threads (per-thread-count speedups);
//!   * multihost epochs/s: work-stealing persistent worker pool at
//!     1/2/4 threads (with the steal count);
//!   * streaming CXLTRC v2 replay: decode-ahead vs inline chunk decode
//!     end-to-end events/s, with the O(chunk) decoded-event residency
//!     bound asserted on every run;
//!   * pipelined epoch execution: `--pipeline` (analysis on a worker
//!     thread, pump one epoch ahead) vs serial epochs/s on a
//!     pump-heavy and an analyze-heavy epoch shape, with the measured
//!     overlap fraction;
//!   * end-to-end coordinator accesses/s, per-event vs batched pump —
//!     the headline number for the paper's "orders of magnitude faster
//!     than cycle-accurate" claim;
//!   * sweep-engine cells/s: a 2×2 comparison grid end-to-end through
//!     the work-stealing cell pool (expansion, runs, sanitize,
//!     artifact assembly), with its delay-ordering invariant asserted
//!     on every iteration.
//!
//! Also emits machine-readable `BENCH_hotpath.json` so future PRs can
//! track the perf trajectory.
//!
//!     cargo bench --offline --bench hotpath
//!
//! Set `HOTPATH_SMOKE=1` (CI does) to shrink workloads and iteration
//! counts ~10x: same JSON schema, same comparisons, minutes → seconds.

use cxlmemsim::alloctrack::AllocTracker;
use cxlmemsim::cache::CacheHierarchy;
use cxlmemsim::coordinator::{run_batched, Coordinator, SimConfig};
use cxlmemsim::fault::FaultPlan;
use cxlmemsim::multihost::run_shared_threads;
use cxlmemsim::prelude::*;
use cxlmemsim::runtime::native::{NativeAnalyzer, NativeBatchAnalyzer};
use cxlmemsim::runtime::shapes;
use cxlmemsim::runtime::{BatchTimingModel, ScanKernel, TimingInputs, TimingModel};
use cxlmemsim::trace::binning::{BinDelta, EpochBins};
use cxlmemsim::trace::{AllocEvent, AllocKind};
use cxlmemsim::util::benchutil::{bench, fmt_secs};
use cxlmemsim::util::json::{self, Json};
use cxlmemsim::util::rng::Rng;
use cxlmemsim::workload::{self, drain_batched};

fn main() {
    let smoke = std::env::var("HOTPATH_SMOKE").map(|v| v != "0").unwrap_or(false);
    // iteration scaler: smoke mode cuts measured iterations ~10x
    let it = |n: usize| if smoke { (n / 10).max(1) } else { n };
    let wl_scale = if smoke { 0.002 } else { 0.01 };

    let topo = builtin::fig2();
    let tensors = TopoTensors::build(&topo, shapes::NUM_POOLS, shapes::NUM_SWITCHES).unwrap();
    let nbins = shapes::NUM_BINS;
    let n = shapes::NUM_POOLS * nbins;
    let mut results: Vec<(&str, Json)> = Vec::new();

    let mut rng = Rng::new(4);
    let reads: Vec<f32> = (0..n).map(|_| rng.below(20) as f32).collect();
    let writes: Vec<f32> = (0..n).map(|_| rng.below(10) as f32).collect();
    let inp = || TimingInputs {
        reads: &reads,
        writes: &writes,
        bin_width: 3906.25,
        bytes_per_ev: 64.0,
    };

    println!("## P1: hot-path microbenchmarks{}\n", if smoke { " (smoke)" } else { "" });

    // --- event-pump throughput -----------------------------------
    // the tracer substrate's raw feed rate: how fast workloads emit
    for wl_name in ["mcf_like", "stream", "wrf_like"] {
        let s = bench(&format!("{wl_name} per-event"), 1, it(5), || {
            let mut wl = workload::by_name(wl_name, wl_scale, 7).unwrap();
            let mut n = 0u64;
            while wl.next_event().is_some() {
                n += 1;
            }
            std::hint::black_box(n);
        });
        let mut wl = workload::by_name(wl_name, wl_scale, 7).unwrap();
        let total = drain_batched(wl.as_mut(), 4096) as f64;
        let per_event_rate = total / s.mean_s;
        let s = bench(&format!("{wl_name} batched"), 1, it(5), || {
            let mut wl = workload::by_name(wl_name, wl_scale, 7).unwrap();
            std::hint::black_box(drain_batched(wl.as_mut(), 4096));
        });
        let batched_rate = total / s.mean_s;
        println!(
            "event pump[{wl_name:9}]: per-event {:>7.1} M ev/s | batched {:>7.1} M ev/s ({:.2}x)",
            per_event_rate / 1e6,
            batched_rate / 1e6,
            batched_rate / per_event_rate
        );
        results.push((
            "event_pump",
            json::obj(vec![
                ("workload", json::s(wl_name)),
                ("per_event_evps", json::num(per_event_rate)),
                ("batched_evps", json::num(batched_rate)),
                ("speedup", json::num(batched_rate / per_event_rate)),
            ]),
        ));
    }

    // --- pool_of lookup cost -------------------------------------
    // a tracker with a realistically fragmented address space
    let mut tracker =
        AllocTracker::new(&topo, cxlmemsim::alloctrack::PolicyKind::CxlOnly.build(&topo));
    let regions = 512u64;
    let region_len = 1u64 << 20;
    for i in 0..regions {
        tracker.on_alloc_event(&AllocEvent {
            kind: AllocKind::Mmap,
            addr: 0x7f00_0000_0000 + i * 2 * region_len,
            len: region_len,
            t_ns: 0.0,
        });
    }
    // spatially local probe stream (the LLC-miss shape: streams/stencils)
    let nprobes = if smoke { 100_000u64 } else { 1_000_000u64 };
    let mut probes: Vec<u64> = Vec::with_capacity(nprobes as usize);
    let mut r = Rng::new(9);
    let mut cur = 0x7f00_0000_0000u64;
    for i in 0..nprobes {
        if i % 4096 == 0 {
            cur = 0x7f00_0000_0000 + r.below(regions) * 2 * region_len;
        }
        probes.push(cur + (i % (region_len / 64)) * 64);
    }
    let (pool_warm, pool_iters) = (2usize, it(10));
    let mut sum = 0u64;
    let s = bench("pool_of fast", pool_warm, pool_iters, || {
        for &a in &probes {
            sum = sum.wrapping_add(tracker.pool_of(a) as u64);
        }
    });
    let fast_rate = probes.len() as f64 / s.mean_s;
    let s = bench("pool_of btree", pool_warm, pool_iters, || {
        for &a in &probes {
            sum = sum.wrapping_add(tracker.pool_of_btree(a) as u64);
        }
    });
    std::hint::black_box(sum);
    let btree_rate = probes.len() as f64 / s.mean_s;
    // only the fast passes (warmup + timed) touch the MRU stats
    let fast_passes = (pool_warm + pool_iters) as f64;
    let mru_hit_rate = tracker.stats.mru_hits as f64 / (fast_passes * probes.len() as f64);
    println!(
        "pool_of:              fast {:>7.1} M/s ({:.1}% MRU hits) | btree {:>7.1} M/s ({:.2}x)",
        fast_rate / 1e6,
        mru_hit_rate * 100.0,
        btree_rate / 1e6,
        fast_rate / btree_rate
    );
    results.push((
        "pool_of",
        json::obj(vec![
            ("regions", json::num(regions as f64)),
            ("fast_lookups_per_s", json::num(fast_rate)),
            ("btree_lookups_per_s", json::num(btree_rate)),
            ("speedup", json::num(fast_rate / btree_rate)),
            ("mru_hits", json::num(tracker.stats.mru_hits as f64)),
        ]),
    ));

    // --- bins recording: scalar record vs staged bulk scatter ----
    // the per-sampled-miss accounting cost inside the epoch driver
    let epoch_ns = 1e6f64;
    let nsamples = if smoke { 100_000usize } else { 1_000_000usize };
    let mut samples: Vec<(usize, bool, f64, f32)> = Vec::with_capacity(nsamples);
    let mut r = Rng::new(11);
    for _ in 0..nsamples {
        samples.push((
            r.below(shapes::NUM_POOLS as u64) as usize,
            r.below(2) == 0,
            r.range_f64(0.0, epoch_ns),
            1.0 + r.below(64) as f32,
        ));
    }
    let mut bins = EpochBins::new(shapes::NUM_POOLS, nbins, epoch_ns);
    let s = bench("bins record", 2, it(10), || {
        bins.clear();
        for &(p, w, t, wt) in &samples {
            bins.record(p, w, t, wt);
        }
    });
    let record_rate = samples.len() as f64 / s.mean_s;
    let mut staged: Vec<BinDelta> = Vec::with_capacity(4096);
    // unpartitioned scatter (the PR-2 path, kept as `record_bulk_seq`)
    let s = bench("bins stage+record_bulk_seq", 2, it(10), || {
        bins.clear();
        for chunk in samples.chunks(4096) {
            staged.clear();
            for &(p, w, t, wt) in chunk {
                bins.stage(p, w, t, wt, &mut staged);
            }
            bins.record_bulk_seq(&staged);
        }
    });
    let bulk_seq_rate = samples.len() as f64 / s.mean_s;
    // pool-partitioned scatter (the current `record_bulk`): the
    // counting sort turns the scatter into contiguous bin runs
    let s = bench("bins stage+record_bulk", 2, it(10), || {
        bins.clear();
        for chunk in samples.chunks(4096) {
            staged.clear();
            for &(p, w, t, wt) in chunk {
                bins.stage(p, w, t, wt, &mut staged);
            }
            bins.record_bulk(&staged);
        }
    });
    std::hint::black_box(bins.total_events);
    let bulk_rate = samples.len() as f64 / s.mean_s;
    println!(
        "bins record:          scalar {:>7.1} M rec/s | bulk-seq {:>7.1} M rec/s | \
         bulk-part {:>7.1} M rec/s ({:.2}x vs scalar, {:.2}x vs seq)",
        record_rate / 1e6,
        bulk_seq_rate / 1e6,
        bulk_rate / 1e6,
        bulk_rate / record_rate,
        bulk_rate / bulk_seq_rate
    );
    results.push((
        "bins_record",
        json::obj(vec![
            ("samples", json::num(samples.len() as f64)),
            ("scalar_recs_per_s", json::num(record_rate)),
            ("bulk_seq_recs_per_s", json::num(bulk_seq_rate)),
            ("bulk_recs_per_s", json::num(bulk_rate)),
            ("speedup", json::num(bulk_rate / record_rate)),
            ("partition_speedup", json::num(bulk_rate / bulk_seq_rate)),
        ]),
    ));

    // --- analyzer invocation cost --------------------------------
    let mut native = NativeAnalyzer::new(&tensors, nbins);
    let s = bench("native analyze", it(50), it(500), || {
        native.analyze(&inp()).unwrap();
    });
    println!(
        "native analyzer:      {:>10}/call  ({:.0} calls/s)",
        fmt_secs(s.mean_s),
        1.0 / s.mean_s
    );
    results.push((
        "native_analyzer",
        json::obj(vec![("mean_s", json::num(s.mean_s))]),
    ));

    // --- scan kernels: exact reference vs blocked max-plus ---------
    // same inputs at the full NUM_BINS=256 shape; `exact` is the
    // golden-pinned scalar recurrence, `blocked` the SIMD-friendly
    // max-plus block scan (tolerance-equal, see native.rs)
    {
        let mut exact = NativeAnalyzer::with_kernel(&tensors, nbins, ScanKernel::Exact);
        let s = bench("scan exact", it(50), it(500), || {
            exact.analyze(&inp()).unwrap();
        });
        let exact_rate = 1.0 / s.mean_s;
        let mut blocked = NativeAnalyzer::with_kernel(&tensors, nbins, ScanKernel::Blocked);
        let s = bench("scan blocked", it(50), it(500), || {
            blocked.analyze(&inp()).unwrap();
        });
        let blocked_rate = 1.0 / s.mean_s;
        println!(
            "scan kernel (B={nbins}):  exact {:>8.0} calls/s | blocked {:>8.0} calls/s ({:.2}x)",
            exact_rate,
            blocked_rate,
            blocked_rate / exact_rate
        );
        results.push((
            "scan_kernel",
            json::obj(vec![
                ("nbins", json::num(nbins as f64)),
                ("exact_calls_per_s", json::num(exact_rate)),
                ("blocked_calls_per_s", json::num(blocked_rate)),
                ("speedup", json::num(blocked_rate / exact_rate)),
            ]),
        ));
    }

    // --- batched analysis: fused kernel vs E scalar calls --------
    let e = shapes::BATCH;
    let mut batcher = NativeBatchAnalyzer::new(&tensors, nbins, e);
    let mut r = Rng::new(5);
    let breads: Vec<f32> = (0..e * n).map(|_| r.below(20) as f32).collect();
    let bwrites: Vec<f32> = (0..e * n).map(|_| r.below(10) as f32).collect();
    let s = bench("native batch analyze", it(20), it(200), || {
        batcher.analyze_batch(&breads, &bwrites, 3906.25, 64.0).unwrap();
    });
    let fused_rate = e as f64 / s.mean_s;
    let s = bench("native scalar xE", it(20), it(200), || {
        for i in 0..e {
            native
                .analyze(&TimingInputs {
                    reads: &breads[i * n..(i + 1) * n],
                    writes: &bwrites[i * n..(i + 1) * n],
                    bin_width: 3906.25,
                    bytes_per_ev: 64.0,
                })
                .unwrap();
        }
    });
    let scalar_rate = e as f64 / s.mean_s;
    // same batch through the blocked kernel (the shipping default)
    let mut blocked_batcher =
        NativeBatchAnalyzer::with_kernel(&tensors, nbins, e, 1, ScanKernel::Blocked);
    let s = bench("native batch blocked", it(20), it(200), || {
        blocked_batcher.analyze_batch(&breads, &bwrites, 3906.25, 64.0).unwrap();
    });
    let blocked_rate = e as f64 / s.mean_s;
    println!(
        "batch analyze ({e:>2}/call): scalar {:>8.0} ep/s | fused {:>8.0} ep/s ({:.2}x) | \
         blocked {:>8.0} ep/s ({:.2}x vs exact)",
        scalar_rate,
        fused_rate,
        fused_rate / scalar_rate,
        blocked_rate,
        blocked_rate / fused_rate
    );
    results.push((
        "batch_analyze",
        json::obj(vec![
            ("batch", json::num(e as f64)),
            ("scalar_epochs_per_s", json::num(scalar_rate)),
            ("fused_epochs_per_s", json::num(fused_rate)),
            ("speedup", json::num(fused_rate / scalar_rate)),
            ("blocked_epochs_per_s", json::num(blocked_rate)),
            ("kernel_speedup", json::num(blocked_rate / fused_rate)),
        ]),
    ));

    // --- sharded batch analysis: per-thread-count speedups ---------
    // the offline-replay regime (long traces => a big E per call, so
    // the per-call shard fan-out amortizes); outputs stay bit-identical
    // for every thread count — only epochs/s moves
    {
        let se = if smoke { 64usize } else { 256 };
        let mut r = Rng::new(6);
        let sreads: Vec<f32> = (0..se * n).map(|_| r.below(20) as f32).collect();
        let swrites: Vec<f32> = (0..se * n).map(|_| r.below(10) as f32).collect();
        // both kernels per thread count: the sharding speedup and the
        // blocked-kernel speedup compound (per-epoch work shrinks)
        let mut per_thread: Vec<(usize, f64, f64)> = Vec::new();
        for threads in [1usize, 2, 4] {
            let mut an = NativeBatchAnalyzer::with_threads(&tensors, nbins, se, threads);
            let s = bench(&format!("sharded batch x{threads}"), it(10), it(100), || {
                an.analyze_batch(&sreads, &swrites, 3906.25, 64.0).unwrap();
            });
            let exact_rate = se as f64 / s.mean_s;
            let mut an = NativeBatchAnalyzer::with_kernel(
                &tensors,
                nbins,
                se,
                threads,
                ScanKernel::Blocked,
            );
            let s = bench(&format!("sharded blocked x{threads}"), it(10), it(100), || {
                an.analyze_batch(&sreads, &swrites, 3906.25, 64.0).unwrap();
            });
            per_thread.push((threads, exact_rate, se as f64 / s.mean_s));
        }
        let base = per_thread[0].1;
        let parts: Vec<String> = per_thread
            .iter()
            .map(|(t, rate, brate)| {
                format!("{t}T {rate:>8.0}/{brate:>8.0} ep/s ({:.2}x)", brate / base)
            })
            .collect();
        println!("batch shard ({se:>3}/call, exact/blocked): {}", parts.join(" | "));
        results.push((
            "batch_analyze_sharded",
            json::obj(vec![
                ("batch", json::num(se as f64)),
                ("kernel_speedup", json::num(per_thread[0].2 / per_thread[0].1)),
                (
                    "per_thread",
                    Json::Arr(
                        per_thread
                            .iter()
                            .map(|(t, rate, brate)| {
                                json::obj(vec![
                                    ("threads", json::num(*t as f64)),
                                    ("epochs_per_s", json::num(*rate)),
                                    ("speedup", json::num(*rate / base)),
                                    ("blocked_epochs_per_s", json::num(*brate)),
                                    ("blocked_speedup", json::num(*brate / base)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ));
    }

    // --- batched replay: native group size 16 vs 256 --------------
    // the offline-replay regime the `--batch-group` knob exists for:
    // a larger native group hands the sharded analyzer more epochs per
    // fan-out, amortizing the per-call worker spawn; identical results
    // (epochs are independent), only epochs/s moves
    {
        let run_group = |group: usize| {
            let mut c = SimConfig::default();
            c.scale = wl_scale;
            c.cache_scale = 64;
            c.backend = AnalyzerBackend::Native;
            c.epoch_ms = 0.05;
            c.analyzer_threads = 4;
            c.batch_group = group;
            let mut wl = workload::by_name("mcf_like", c.scale, 7).unwrap();
            run_batched(&topo, &c, wl.as_mut()).unwrap()
        };
        let measure = |group: usize| {
            let mut best = 0.0f64;
            let mut epochs = 0u64;
            for _ in 0..it(10).max(3) {
                let rep = run_group(group);
                epochs = rep.epochs_run;
                best = best.max(rep.epochs_run as f64 / rep.wall_s);
            }
            (best, epochs)
        };
        let (rate16, epochs16) = measure(16);
        let (rate256, epochs256) = measure(256);
        assert_eq!(epochs16, epochs256, "group size must not change the simulation");
        println!(
            "replay group:         16/call {rate16:>8.0} ep/s | 256/call {rate256:>8.0} ep/s \
             ({:.2}x)",
            rate256 / rate16
        );
        results.push((
            "replay_group",
            json::obj(vec![
                ("epochs", json::num(epochs16 as f64)),
                ("group16_epochs_per_s", json::num(rate16)),
                ("group256_epochs_per_s", json::num(rate256)),
                ("speedup", json::num(rate256 / rate16)),
            ]),
        ));
    }

    // --- streaming trace replay: decode-ahead vs inline ------------
    // the CXLTRC v2 regime: a run-heavy recorded trace replayed from
    // disk with O(chunk) resident decoded events; the decode-ahead
    // thread overlaps RLE decode with the analyzer so wall-clock
    // approaches max(decode, analyze). Gated as
    // replay_stream.events_per_s. The in-memory replay reference runs
    // in smoke mode only — fully decoding the 100M-event full trace
    // is exactly the O(trace) allocation streaming exists to avoid.
    {
        use cxlmemsim::trace::io::{self as tio, V2_DEFAULT_CHUNK_EVENTS};
        use cxlmemsim::trace::stream::DECODE_AHEAD_DEPTH;
        use cxlmemsim::trace::{Access, WlEvent};
        use cxlmemsim::workload::TraceReplay;

        let total_events: u64 = if smoke { 2_000_000 } else { 100_000_000 };
        let path = std::env::temp_dir()
            .join(format!("cxlms-bench-stream-{}.bin", std::process::id()));
        let f = std::fs::File::create(&path).unwrap();
        let mut w = tio::V2Writer::new(f).unwrap(); // default 64Ki-event chunks
        let mut rng = Rng::new(0x5eed);
        let tr_regions = 64u64;
        let tr_len = 1u64 << 24; // 16 MiB each
        let tr_base = 0x7fb0_0000_0000u64;
        for i in 0..tr_regions {
            w.push(WlEvent::Alloc(AllocEvent {
                kind: AllocKind::Mmap,
                addr: tr_base + i * 2 * tr_len,
                len: tr_len,
                t_ns: 0.0,
            }))
            .unwrap();
        }
        // run-heavy access mix (long strided sweeps + occasional
        // singles): the shape RLE compresses and real traces exhibit
        let mut slab: Vec<WlEvent> = Vec::with_capacity(1 << 16);
        let mut emitted = 0u64;
        while emitted < total_events {
            slab.clear();
            let want = ((total_events - emitted) as usize).min(1 << 16);
            while slab.len() < want {
                let r = rng.below(tr_regions);
                let start = tr_base + r * 2 * tr_len + (rng.below(tr_len / 2) & !63);
                let is_write = rng.below(4) == 0;
                if rng.below(16) == 0 {
                    slab.push(WlEvent::Access(Access { addr: start, is_write }));
                } else {
                    let stride = if rng.below(4) == 0 { 4096u64 } else { 64 };
                    let run = (want - slab.len()).min(2048);
                    for k in 0..run as u64 {
                        slab.push(WlEvent::Access(Access { addr: start + k * stride, is_write }));
                    }
                }
            }
            w.push_slice(&slab).unwrap();
            emitted += slab.len() as u64;
        }
        let summary = w.finish().unwrap();
        let file_bytes = std::fs::metadata(&path).unwrap().len();

        let cfg_stream = || {
            let mut c = SimConfig::default();
            c.scale = wl_scale;
            c.cache_scale = 64;
            c.backend = AnalyzerBackend::Native;
            c.epoch_ms = 0.05;
            c.analyzer_threads = 4;
            c.batch_group = 256;
            c
        };
        let run_stream = |ahead: bool| {
            let c = cfg_stream();
            let mut st = TraceStream::open_with(path.to_str().unwrap(), ahead).unwrap();
            let rep = run_batched(&topo, &c, &mut st).unwrap();
            assert!(st.take_error().is_none(), "clean trace must replay cleanly");
            let bound = (DECODE_AHEAD_DEPTH as u64 + 2) * st.max_chunk_events();
            assert!(
                st.peak_decoded_in_flight() <= bound,
                "decoded-event residency {} broke the O(chunk) bound {bound}",
                st.peak_decoded_in_flight()
            );
            (summary.events as f64 / rep.wall_s, st.peak_decoded_in_flight())
        };
        let measure = |ahead: bool| {
            let mut best = 0.0f64;
            let mut peak = 0u64;
            for _ in 0..it(5).max(2) {
                let (rate, p) = run_stream(ahead);
                best = best.max(rate);
                peak = peak.max(p);
            }
            (best, peak)
        };
        let (ahead_rate, peak_in_flight) = measure(true);
        let (inline_rate, _) = measure(false);
        // in-memory reference (smoke only): replay the fully decoded
        // trace to show streaming gives up ~nothing in throughput
        let mem_rate = if smoke {
            let bytes = std::fs::read(&path).unwrap();
            let events = tio::read_binary_v2(&bytes).unwrap();
            let mut best = 0.0f64;
            for _ in 0..it(5).max(2) {
                let c = cfg_stream();
                let mut wl = TraceReplay::new("replay:mem", events.clone());
                let rep = run_batched(&topo, &c, &mut wl).unwrap();
                best = best.max(summary.events as f64 / rep.wall_s);
            }
            best
        } else {
            0.0
        };
        std::fs::remove_file(&path).ok();
        println!(
            "replay stream:        decode-ahead {:>7.1} M ev/s | inline {:>7.1} M ev/s \
             ({:.2}x) | peak in-flight {peak_in_flight}",
            ahead_rate / 1e6,
            inline_rate / 1e6,
            ahead_rate / inline_rate
        );
        results.push((
            "replay_stream",
            json::obj(vec![
                ("events", json::num(summary.events as f64)),
                ("chunks", json::num(summary.chunks as f64)),
                ("file_bytes", json::num(file_bytes as f64)),
                ("chunk_events", json::num(V2_DEFAULT_CHUNK_EVENTS as f64)),
                ("events_per_s", json::num(ahead_rate)),
                ("inline_events_per_s", json::num(inline_rate)),
                ("decode_ahead_speedup", json::num(ahead_rate / inline_rate)),
                ("inmemory_events_per_s", json::num(mem_rate)),
                ("peak_decoded_in_flight", json::num(peak_in_flight as f64)),
            ]),
        ));
    }

    // --- fault injection: the fault-free path must stay free -------
    // the RAS fault subsystem rides the epoch barrier; with no plan
    // configured none of it is even constructed, so fault-free replay
    // must run at full speed (gated as fault_epoch.faultfree_epochs_per_s).
    // armed-but-idle (plan resolved, no window ever opens) and full
    // chaos (storms + a mid-run pool-offline failover) are reported
    // alongside for the trajectory file.
    {
        let run_fault = |plan: Option<FaultPlan>| {
            let mut c = SimConfig::default();
            c.scale = wl_scale;
            c.cache_scale = 64;
            c.backend = AnalyzerBackend::Native;
            c.epoch_ms = 0.05;
            c.analyzer_threads = 4;
            c.faults = plan;
            let mut wl = workload::by_name("mcf_like", c.scale, 7).unwrap();
            run_batched(&topo, &c, wl.as_mut()).unwrap()
        };
        let measure = |plan: Option<FaultPlan>| {
            let mut best = 0.0f64;
            let mut last = None;
            for _ in 0..it(10).max(3) {
                let rep = run_fault(plan.clone());
                best = best.max(rep.epochs_run as f64 / rep.wall_s);
                last = Some(rep);
            }
            (best, last.unwrap())
        };
        let (free_rate, free_rep) = measure(None);
        let e = free_rep.epochs_run;
        let armed =
            FaultPlan::parse_inline(&format!("storm:pool1@{}+4:rd=250", e * 1000)).unwrap();
        let (armed_rate, armed_rep) = measure(Some(armed));
        let chaos = FaultPlan::parse_inline(&format!(
            "storm:pool0@1+{}:rd=250,wr=125;offline:pool0@{}",
            (e / 4).max(1),
            (e / 2).max(1)
        ))
        .unwrap();
        let (chaos_rate, chaos_rep) = measure(Some(chaos));
        assert_eq!(armed_rep.faults_injected, 0, "armed plan must stay idle");
        assert_eq!(free_rep.epochs_run, chaos_rep.epochs_run, "faults changed the event stream");
        if e >= 4 {
            assert_eq!(chaos_rep.pools_offline, 1, "offline event must fire");
        }
        println!(
            "fault epoch:          fault-free {free_rate:>8.0} ep/s | armed {armed_rate:>8.0} \
             ep/s ({:.2}x) | chaos {chaos_rate:>8.0} ep/s ({:.2}x)",
            free_rate / armed_rate,
            free_rate / chaos_rate
        );
        results.push((
            "fault_epoch",
            json::obj(vec![
                ("epochs", json::num(e as f64)),
                ("faultfree_epochs_per_s", json::num(free_rate)),
                ("armed_epochs_per_s", json::num(armed_rate)),
                ("chaos_epochs_per_s", json::num(chaos_rate)),
                ("armed_overhead", json::num(free_rate / armed_rate)),
                ("failover_migrated_bytes", json::num(chaos_rep.failover_migrated_bytes as f64)),
            ]),
        ));

        // --- armed-but-idle MTBF soak: generated plans ride the same
        // ~free barrier. A seeded soak draws its whole schedule up
        // front, so a plan whose first event lies past the horizon
        // must cost what any armed-idle plan costs — gated as
        // fault_soak.armed_epochs_per_s. The seed is fixed but `e` is
        // machine-measured, so grow the MTBF until every drawn start
        // provably clears the horizon instead of assuming one draw.
        let mut mult = 1_000u64;
        let soak = loop {
            let spec = format!(
                "mtbf={},epochs={},kinds=storm|retrain|offline+online,window=4,warmup=2,\
                 rd=120,wr=60",
                e.max(1) * mult,
                e.max(1) * mult * 4
            );
            let p = FaultPlan::generate(7, &spec).unwrap();
            if !p.events.is_empty() && p.events.iter().all(|ev| ev.start > e) {
                break p;
            }
            mult *= 10;
        };
        let (soak_rate, soak_rep) = measure(Some(soak));
        assert_eq!(soak_rep.faults_injected, 0, "soak plan must stay idle past the horizon");
        println!(
            "fault soak:           armed-idle {soak_rate:>8.0} ep/s ({:.2}x vs fault-free)",
            free_rate / soak_rate
        );
        results.push((
            "fault_soak",
            json::obj(vec![
                ("epochs", json::num(e as f64)),
                ("armed_epochs_per_s", json::num(soak_rate)),
                ("armed_overhead", json::num(free_rate / soak_rate)),
            ]),
        ));
    }

    // --- pipelined epoch execution: pump/analysis overlap ----------
    // two epoch shapes bound the win: long epochs (pump-heavy — the
    // analyzer call is rare and hides entirely) and short epochs
    // (analyze-heavy — the analyzer runs constantly, so overlap pays
    // most). No hard speedup assert: a 1-core runner legitimately
    // shows none; the gated key is the absolute pipelined rate and the
    // trajectory file carries both speedups for inspection.
    {
        let run_pipe = |epoch_ms: f64, pipeline: bool| {
            let mut c = SimConfig::default();
            c.scale = wl_scale;
            c.cache_scale = 64;
            c.backend = AnalyzerBackend::Native;
            c.epoch_ms = epoch_ms;
            c.pipeline = pipeline;
            let mut sim = Coordinator::new(topo.clone(), c).unwrap();
            sim.run_workload("mcf_like").unwrap()
        };
        let measure = |epoch_ms: f64, pipeline: bool| {
            let mut best = 0.0f64;
            let mut last = None;
            for _ in 0..it(10).max(3) {
                let rep = run_pipe(epoch_ms, pipeline);
                best = best.max(rep.epochs_run as f64 / rep.wall_s);
                last = Some(rep);
            }
            (best, last.unwrap())
        };
        let (ph_serial, ph_srep) = measure(0.2, false);
        let (ph_piped, ph_prep) = measure(0.2, true);
        assert_eq!(ph_srep.total_misses, ph_prep.total_misses, "pump-heavy pipelined diverged");
        let (ah_serial, ah_srep) = measure(0.02, false);
        let (ah_piped, ah_prep) = measure(0.02, true);
        assert_eq!(
            ah_srep.total_misses, ah_prep.total_misses,
            "analyze-heavy pipelined diverged"
        );
        assert_eq!(ah_prep.pipeline_depth, 1, "no stack: the pipeline must overlap");
        println!(
            "pipeline overlap:     pump-heavy {ph_serial:>7.0} -> {ph_piped:>7.0} ep/s \
             ({:.2}x) | analyze-heavy {ah_serial:>7.0} -> {ah_piped:>7.0} ep/s ({:.2}x, \
             {:.0}% hidden)",
            ph_piped / ph_serial,
            ah_piped / ah_serial,
            ah_prep.overlap_frac * 100.0
        );
        results.push((
            "pipeline_overlap",
            json::obj(vec![
                ("pump_heavy_serial_epochs_per_s", json::num(ph_serial)),
                ("pump_heavy_pipelined_epochs_per_s", json::num(ph_piped)),
                ("pump_heavy_speedup", json::num(ph_piped / ph_serial)),
                ("analyze_heavy_serial_epochs_per_s", json::num(ah_serial)),
                ("analyze_heavy_pipelined_epochs_per_s", json::num(ah_piped)),
                ("analyze_heavy_speedup", json::num(ah_piped / ah_serial)),
                ("pipelined_epochs_per_s", json::num(ah_piped)),
                ("overlap_frac", json::num(ah_prep.overlap_frac)),
            ]),
        ));
    }

    // --- policy engine overhead per epoch ------------------------
    // the zero-cost guarantee, measured: an installed-but-empty
    // PolicyStack must cost ~nothing per epoch vs no stack at all;
    // a full hotness+prefetch+rebalance stack is the reference point
    {
        use cxlmemsim::policy::{PolicySpec, PolicyStack};
        let mut pbins = EpochBins::new(shapes::NUM_POOLS, nbins, 1e6);
        for i in 0..nbins {
            pbins.record(1, false, i as f64 * (1e6 / nbins as f64), 10.0);
        }
        let mut ptracker =
            AllocTracker::new(&topo, cxlmemsim::alloctrack::PolicyKind::CxlOnly.build(&topo));
        ptracker.on_alloc_event(&AllocEvent {
            kind: AllocKind::Mmap,
            addr: 0x1000,
            len: 1 << 20,
            t_ns: 0.0,
        });
        let out = NativeAnalyzer::new(&tensors, nbins).analyze(&inp()).unwrap();
        let mut empty = PolicyStack::new(0.0625);
        let s = bench("policy empty stack", it(1000), it(100_000), || {
            empty.before_analysis(&mut pbins, &mut ptracker, 64.0);
            std::hint::black_box(empty.after_analysis(&pbins, &out, &mut ptracker, 64.0));
        });
        let empty_ns = s.mean_s * 1e9;
        let mut full = PolicySpec::parse("hotness:3,prefetch:0.5,rebalance")
            .unwrap()
            .build(0.0625);
        let s = bench("policy full stack", it(100), it(10_000), || {
            full.before_analysis(&mut pbins, &mut ptracker, 64.0);
            std::hint::black_box(full.after_analysis(&pbins, &out, &mut ptracker, 64.0));
        });
        let full_ns = s.mean_s * 1e9;
        println!(
            "policy epoch:         empty stack {empty_ns:>8.1} ns/epoch | \
             hotness+prefetch+rebalance {full_ns:>8.1} ns/epoch"
        );
        results.push((
            "policy_epoch",
            json::obj(vec![
                ("empty_stack_ns_per_epoch", json::num(empty_ns)),
                ("full_stack_ns_per_epoch", json::num(full_ns)),
            ]),
        ));
    }

    #[cfg(feature = "pjrt")]
    {
        use cxlmemsim::runtime::pjrt::{PjrtAnalyzer, PjrtBatchAnalyzer};
        let dir = shapes::artifacts_dir();
        let mut pjrt = PjrtAnalyzer::new(&tensors, nbins, &dir).unwrap();
        let s = bench("pjrt analyze", 20, 200, || {
            pjrt.analyze(&inp()).unwrap();
        });
        println!(
            "pjrt analyzer:        {:>10}/call  ({:.0} calls/s)",
            fmt_secs(s.mean_s),
            1.0 / s.mean_s
        );
        let mut rng = Rng::new(5);
        let mut batch = PjrtBatchAnalyzer::new(&tensors, nbins, &dir).unwrap();
        let e = batch.batch;
        let breads: Vec<f32> = (0..e * n).map(|_| rng.below(20) as f32).collect();
        let bwrites: Vec<f32> = (0..e * n).map(|_| rng.below(10) as f32).collect();
        let s = bench("pjrt batch analyze", 10, 100, || {
            batch.analyze_batch(&breads, &bwrites, 3906.25, 64.0).unwrap();
        });
        println!(
            "pjrt batch ({e:>2}/call): {:>10}/call  ({:.0} epochs/s effective)",
            fmt_secs(s.mean_s),
            e as f64 / s.mean_s
        );
    }

    // --- cache substrate cost ------------------------------------
    // worst case: uniform-random over 1 GB, every access an LLC miss
    let naddr = nprobes; // same smoke scaling as the probe stream
    let mut cache = CacheHierarchy::scaled(1);
    let addrs: Vec<u64> = (0..naddr).map(|_| rng.below(1 << 30) & !63).collect();
    let s = bench("cache misses", 1, it(10), || {
        for &a in &addrs {
            cache.access(a, a & 64 != 0);
        }
    });
    println!(
        "cache (all-miss):     {:>10}/pass  ({:.1} M accesses/s)",
        fmt_secs(s.mean_s),
        addrs.len() as f64 / s.mean_s / 1e6
    );
    // common case: hot working set, L1-resident
    let mut cache = CacheHierarchy::scaled(1);
    let hot: Vec<u64> = (0..naddr).map(|_| rng.below(512) * 64).collect();
    let s = bench("cache hits", 1, it(10), || {
        for &a in &hot {
            cache.access(a, a & 64 != 0);
        }
    });
    println!(
        "cache (L1-hot):       {:>10}/pass  ({:.1} M accesses/s)",
        fmt_secs(s.mean_s),
        hot.len() as f64 / s.mean_s / 1e6
    );

    // --- multihost epochs/s: work-stealing pool, per thread count --
    // short epochs make the per-epoch coordination cost visible — this
    // is exactly the regime the persistent work-stealing pool (vs a
    // fresh thread scope per epoch) is for
    let mh_hosts = if smoke { 4usize } else { 8usize };
    let mh = |threads: usize| {
        let mut c = SimConfig::default();
        c.scale = 0.002;
        c.cache_scale = 64;
        c.epoch_ms = 0.05;
        c.backend = AnalyzerBackend::Native;
        let hosts: Vec<Box<dyn Workload>> = (0..mh_hosts)
            .map(|i| workload::by_name("stream", c.scale, i as u64).unwrap())
            .collect();
        run_shared_threads(&topo, &c, hosts, threads).unwrap()
    };
    // per-thread-count sweep: the work-stealing pool must scale with
    // workers while every run stays bit-identical (same epoch count)
    let mut mh_runs: Vec<(usize, f64, u64)> = Vec::new();
    let mut mh_epochs = 0u64;
    for threads in [1usize, 2, 4] {
        let rep = mh(threads);
        if threads == 1 {
            mh_epochs = rep.epochs;
        } else {
            assert_eq!(rep.epochs, mh_epochs, "multihost pipelines diverged");
        }
        mh_runs.push((threads, rep.epochs as f64 / rep.wall_s, rep.steals));
    }
    let one_rate = mh_runs[0].1;
    let (par_threads, many_rate, steals) = *mh_runs.last().unwrap();
    let parts: Vec<String> = mh_runs
        .iter()
        .map(|(t, rate, _)| format!("{t}T {rate:>7.0} ep/s ({:.2}x)", rate / one_rate))
        .collect();
    println!("multihost[{mh_hosts} hosts]:    {} | {steals} steals", parts.join(" | "));
    results.push((
        "multihost_epoch",
        json::obj(vec![
            ("hosts", json::num(mh_hosts as f64)),
            ("threads", json::num(par_threads as f64)),
            ("epochs", json::num(mh_epochs as f64)),
            ("single_epochs_per_s", json::num(one_rate)),
            ("pooled_epochs_per_s", json::num(many_rate)),
            ("speedup", json::num(many_rate / one_rate)),
            ("steals", json::num(steals as f64)),
            (
                "per_thread",
                Json::Arr(
                    mh_runs
                        .iter()
                        .map(|(t, rate, st)| {
                            json::obj(vec![
                                ("threads", json::num(*t as f64)),
                                ("epochs_per_s", json::num(*rate)),
                                ("speedup", json::num(*rate / one_rate)),
                                ("steals", json::num(*st as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    ));

    // --- end-to-end coordinator: per-event vs batched pump -------
    let run_coord = |event_batch: usize| {
        let mut cfg = SimConfig::default();
        cfg.scale = wl_scale;
        cfg.cache_scale = if smoke { 64 } else { 1 };
        cfg.backend = AnalyzerBackend::Native;
        cfg.event_batch = event_batch;
        let mut sim = Coordinator::new(topo.clone(), cfg).unwrap();
        sim.run_workload("mcf_like").unwrap()
    };
    let per_event = run_coord(1);
    let batched = run_coord(4096);
    let pe_rate = per_event.total_accesses as f64 / per_event.wall_s;
    let ba_rate = batched.total_accesses as f64 / batched.wall_s;
    assert_eq!(per_event.total_misses, batched.total_misses, "pipelines diverged");
    println!(
        "coordinator[mcf_like]: per-event {:>6.2} M acc/s | batched {:>6.2} M acc/s ({:.2}x)",
        pe_rate / 1e6,
        ba_rate / 1e6,
        ba_rate / pe_rate
    );
    results.push((
        "coordinator_e2e",
        json::obj(vec![
            ("workload", json::s("mcf_like")),
            ("per_event_accps", json::num(pe_rate)),
            ("batched_accps", json::num(ba_rate)),
            ("speedup", json::num(ba_rate / pe_rate)),
            ("epochs", json::num(batched.epochs_run as f64)),
            ("accesses", json::num(batched.total_accesses as f64)),
        ]),
    ));

    // --- sweep engine: grid cells/s through the worker pool -------
    // end-to-end cost of one comparison cell (spec expansion + cell
    // run + sanitize + artifact assembly), 2x2 grid on 2 workers
    {
        let spec = SweepSpec::parse(concat!(
            "name = \"bench\"\n",
            "workers = 2\n",
            "[grid]\n",
            "topo = [\"direct\", \"fig2\"]\n",
            "workload = [\"stream\", \"zipfian\"]\n",
            "[config]\n",
            "scale = 0.002\n",
            "cache_scale = 64\n",
            "epoch_ms = 0.1\n",
            "max_epochs = 20\n",
            "[baseline]\n",
            "topo = \"direct\"\n",
            "[[invariant]]\n",
            "metric = \"delay_ms\"\n",
            "axis = \"topo\"\n",
            "order = [\"direct\", \"fig2\"]\n",
            "rel_tol = 0.02\n",
        ))
        .unwrap();
        let cells = 4.0;
        let opts = SweepOptions::default();
        let s = bench("sweep 2x2", 1, it(5), || {
            let out = cxlmemsim::sweep::run_spec(&spec, &opts);
            assert_eq!(out.cell_failures, 0, "bench sweep cell failed");
            assert_eq!(out.invariant_failures, 0, "bench sweep ordering broke");
        });
        let cells_per_s = cells / s.median_s;
        println!(
            "sweep[2x2 grid  ]: {:>10}/cell, {:.1} cells/s",
            fmt_secs(s.median_s / cells),
            cells_per_s
        );
        results.push((
            "sweep",
            json::obj(vec![
                ("cells", json::num(cells)),
                ("workers", json::num(2.0)),
                ("cells_per_s", json::num(cells_per_s)),
            ]),
        ));
    }

    #[cfg(feature = "pjrt")]
    {
        let mut cfg = SimConfig::default();
        cfg.scale = 0.01;
        cfg.cache_scale = 1;
        cfg.backend = AnalyzerBackend::Pjrt;
        let mut sim = Coordinator::new(topo.clone(), cfg).unwrap();
        let rep = sim.run_workload("mcf_like").unwrap();
        println!(
            "coordinator[pjrt  ]: {:>10} wall, {} epochs ({:.0} epochs/s), {:.1} M accesses/s",
            fmt_secs(rep.wall_s),
            rep.epochs_run,
            rep.epochs_run as f64 / rep.wall_s,
            rep.total_accesses as f64 / rep.wall_s / 1e6
        );
    }

    // --- machine-readable trajectory file ------------------------
    let doc = json::obj(vec![
        ("bench", json::s("hotpath")),
        (
            "results",
            Json::Arr(
                results
                    .into_iter()
                    .map(|(name, v)| json::obj(vec![("name", json::s(name)), ("data", v)]))
                    .collect(),
            ),
        ),
    ]);
    std::fs::write("BENCH_hotpath.json", doc.to_string()).ok();
    println!("\nwrote BENCH_hotpath.json");
}

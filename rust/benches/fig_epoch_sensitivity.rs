//! Bench A1 — epoch-length sensitivity: the tool's central design
//! trade-off (paper §3: the Timer divides execution into epochs).
//! Shorter epochs track bursts more faithfully but cost more analyzer
//! invocations; longer epochs amortize but blur congestion. Regenerates
//! the accuracy-vs-overhead curve.
//!
//!     cargo bench --offline --bench fig_epoch_sensitivity

use cxlmemsim::coordinator::{Coordinator, SimConfig};
use cxlmemsim::multihost;
use cxlmemsim::prelude::*;
use cxlmemsim::util::benchutil::markdown_table;
use cxlmemsim::workload;

fn main() {
    let scale: f64 = std::env::var("CXLMEMSIM_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.01);

    let epochs_ms = [0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0];

    // --- part 1: single host (latency-dominated) -----------------
    // delay is count-based here, so the model must be *invariant* to
    // epoch length while analyzer invocations drop linearly.
    println!("## A1a: epoch length, single host (mcf_like, fig2, scale {scale})\n");
    let mut rows = Vec::new();
    let mut results = Vec::new();
    for &epoch_ms in &epochs_ms {
        let mut cfg = SimConfig::default();
        cfg.scale = scale;
        cfg.cache_scale = 16;
        cfg.backend = AnalyzerBackend::Native;
        cfg.epoch_ms = epoch_ms;
        let mut sim = Coordinator::new(builtin::fig2(), cfg).unwrap();
        let rep = sim.run_workload("mcf_like").unwrap();
        results.push((epoch_ms, rep.sim_slowdown(), rep.epochs_run, rep.wall_s));
        rows.push(vec![
            format!("{epoch_ms}"),
            rep.epochs_run.to_string(),
            format!("{:.4}x", rep.sim_slowdown()),
            format!("{:.3}", rep.delay_ns / 1e6),
            format!("{:.4}", rep.wall_s),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["Epoch (ms)", "Epochs", "SimSlowdown", "Delay (ms)", "Wall (s)"],
            &rows
        )
    );
    let ref_slow = results[0].1;
    let worst = results
        .iter()
        .map(|(_, s, _, _)| (s / ref_slow - 1.0).abs())
        .fold(0.0f64, f64::max);
    println!(
        "\nlatency-delay drift vs 0.1 ms epochs: {:.2}% (must be ~0: the paper's \
         count x latency rule is binning-invariant)",
        worst * 100.0
    );
    assert!(worst < 0.05, "latency delay must not depend on epoch length");
    assert!(
        results[0].2 > results.last().unwrap().2,
        "finer epochs must mean more analyzer invocations"
    );

    // --- part 2: shared switch (congestion-sensitive) -------------
    // three hosts saturate the switch; congestion *does* depend on how
    // finely bursts are resolved, so epoch length now matters.
    println!("\n## A1b: epoch length under contention (3x stream, fig2)\n");
    let mut rows = Vec::new();
    let mut cong = Vec::new();
    for &epoch_ms in &epochs_ms {
        let mut cfg = SimConfig::default();
        cfg.scale = scale.min(0.005);
        cfg.cache_scale = 32;
        cfg.backend = AnalyzerBackend::Native;
        cfg.epoch_ms = epoch_ms;
        let hosts: Vec<_> = (0..3)
            .map(|i| workload::by_name("stream", cfg.scale, cfg.seed + i).unwrap())
            .collect();
        let rep = multihost::run_shared(&builtin::fig2(), &cfg, hosts).unwrap();
        cong.push(rep.cong_delay_ns);
        rows.push(vec![
            format!("{epoch_ms}"),
            rep.epochs.to_string(),
            format!("{:.3}", rep.cong_delay_ns / 1e6),
            format!("{:.3}", rep.bwd_delay_ns / 1e6),
            format!("{:.3}x", rep.mean_slowdown()),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["Epoch (ms)", "Epochs", "Cong (ms)", "BW (ms)", "Mean slowdown"],
            &rows
        )
    );
    println!(
        "\ncongestion is burst-resolution-sensitive: coarser epochs smear bursts \
         across wider bins (bin width = epoch/256), shifting the congestion estimate."
    );
    assert!(
        cong.iter().any(|c| *c > 0.0),
        "contended hosts must show congestion somewhere in the sweep"
    );
}

//! Bench A3 — topology sweep (the §1 procurement use-case around
//! Figure 1): simulated slowdown per topology per workload. The figure
//! this regenerates is the delay-vs-topology series the paper's
//! Figure-1 discussion implies: deeper hierarchies / shared switches
//! cost more; directly-attached pools cost least.
//!
//!     cargo bench --offline --bench fig_topology_sweep

use cxlmemsim::coordinator::{Coordinator, SimConfig};
use cxlmemsim::prelude::*;
use cxlmemsim::util::benchutil::markdown_table;

fn main() {
    let scale: f64 = std::env::var("CXLMEMSIM_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.01);
    let mut cfg = SimConfig::default();
    cfg.scale = scale;
    cfg.cache_scale = 16;
    cfg.backend = AnalyzerBackend::Native;

    println!("## A3: topology sweep (scale {scale})\n");
    let workloads = ["stream", "mcf_like", "zipfian"];
    let topos = ["direct", "fig1", "fig2", "deep", "wide", "pooled"];
    let mut rows = Vec::new();
    let mut per_topo: Vec<(String, f64)> = Vec::new();
    for t in topos {
        let topo = Topology::resolve(t).unwrap();
        let mut geo = 0.0;
        for wl in workloads {
            let mut sim = Coordinator::new(topo.clone(), cfg.clone()).unwrap();
            let rep = sim.run_workload(wl).unwrap();
            geo += rep.sim_slowdown().ln();
            rows.push(vec![
                t.to_string(),
                wl.to_string(),
                format!("{:.3}x", rep.sim_slowdown()),
                format!("{:.3}", rep.lat_delay_ns / 1e6),
                format!("{:.3}", rep.cong_delay_ns / 1e6),
                format!("{:.3}", rep.bwd_delay_ns / 1e6),
            ]);
        }
        per_topo.push((t.to_string(), (geo / workloads.len() as f64).exp()));
    }
    println!(
        "{}",
        markdown_table(
            &["Topology", "Workload", "Slowdown", "Lat(ms)", "Cong(ms)", "BW(ms)"],
            &rows
        )
    );
    println!("\ngeomean slowdown per topology:");
    for (t, g) in &per_topo {
        println!("  {t:8} {g:.3}x");
    }
    // shape assertions: direct < deep (depth costs), direct < pooled
    let get = |name: &str| per_topo.iter().find(|(t, _)| t == name).unwrap().1;
    assert!(get("direct") < get("deep"), "depth must cost latency");
    assert!(get("direct") < get("pooled"), "rack pooling must cost latency");
}

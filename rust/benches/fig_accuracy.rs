//! Bench A4 — epoch-model accuracy vs the detailed event-driven
//! simulator: for every Table-1 workload, compare the *simulated
//! slowdown* both models predict for the same topology/placement. The
//! epoch model must preserve the detailed model's ranking and stay
//! within a small factor — that is the accuracy claim an epoch-sampled
//! tool can make (the paper leaves accuracy to future validation; this
//! bench is our substitute evidence).
//!
//!     cargo bench --offline --bench fig_accuracy

use cxlmemsim::alloctrack::PolicyKind;
use cxlmemsim::coordinator::{Coordinator, SimConfig};
use cxlmemsim::gem5like::DetailedSim;
use cxlmemsim::prelude::*;
use cxlmemsim::util::benchutil::markdown_table;
use cxlmemsim::workload;

fn main() {
    let scale: f64 = std::env::var("CXLMEMSIM_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.005);
    let mut cfg = SimConfig::default();
    cfg.scale = scale;
    cfg.cache_scale = 16;
    cfg.backend = AnalyzerBackend::Native;
    let topo = builtin::fig2();

    println!("## A4: epoch model vs detailed model (fig2, scale {scale})\n");
    let mut rows = Vec::new();
    let mut pairs = Vec::new();
    for wl_name in TABLE1_WORKLOADS {
        let mut sim = Coordinator::new(topo.clone(), cfg.clone()).unwrap();
        let rep = sim.run_workload(wl_name).unwrap();
        let epoch_slow = rep.sim_slowdown();

        let mut det = DetailedSim::new(topo.clone(), cfg.cache_scale, PolicyKind::CxlOnly);
        let mut wl = workload::by_name(wl_name, scale, cfg.seed).unwrap();
        let det_rep = det.run(wl.as_mut());
        // detailed "native" = same workload, all-local placement
        let mut det_local = DetailedSim::new(topo.clone(), cfg.cache_scale, PolicyKind::LocalOnly);
        let mut wl = workload::by_name(wl_name, scale, cfg.seed).unwrap();
        let det_local_rep = det_local.run(wl.as_mut());
        let det_slow = det_rep.simulated_ns / det_local_rep.simulated_ns;

        pairs.push((wl_name.to_string(), epoch_slow, det_slow));
        rows.push(vec![
            wl_name.to_string(),
            format!("{epoch_slow:.3}x"),
            format!("{det_slow:.3}x"),
            format!("{:.2}", epoch_slow / det_slow),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["Benchmark", "Epoch model", "Detailed model", "Ratio"],
            &rows
        )
    );
    // shape: both agree CXL hurts (slowdown > 1) on miss-heavy loads,
    // and the mean ratio is within a modest band.
    let ratios: Vec<f64> = pairs.iter().map(|(_, e, d)| e / d).collect();
    let geo = (ratios.iter().map(|r| r.ln()).sum::<f64>() / ratios.len() as f64).exp();
    println!("\ngeomean epoch/detailed slowdown ratio: {geo:.2} (1.0 = perfect agreement)");
    assert!(
        (0.2..5.0).contains(&geo),
        "epoch model drifted out of band vs detailed: {geo}"
    );
}
